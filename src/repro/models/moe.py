"""Mixture-of-Experts layer: top-k routing, capacity dispatch, expert parallel.

Two execution paths with identical semantics (the single-device path is the
test oracle for the distributed one):

* ``mesh`` given — expert parallelism via ``shard_map``: experts shard over the
  "model" mesh axis; every model rank routes the (batch-sharded, model-
  replicated) token block to its local experts through a capacity-bounded
  scatter buffer, runs the expert FFNs locally, and the partial outputs are
  psum'd over "model".  The dispatch buffer is (E_local, C_local, D) — per
  data-shard capacity, so no tensor ever carries global token count × expert
  count (the classic GShard dispatch blow-up).

* ``mesh=None`` — reference: same routing math, experts applied via masked
  dense einsum (affordable at test scale).

Router aux losses (load-balance + z-loss) are returned alongside the output.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

# jax >= 0.6 promotes shard_map to the top-level namespace; 0.4.x keeps it
# under jax.experimental — resolve once so both versions run the same path
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised on jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from .config import ModelConfig
from .layers import P, mlp_spec, swiglu


def moe_spec(cfg: ModelConfig) -> Dict[str, P]:
    m, d = cfg.moe, cfg.d_model
    spec = {
        "router": P((d, m.num_experts), ("embed", None), scale=0.02,
                    dtype=jnp.float32),
        "w_gate": P((m.num_experts, d, m.d_ff_expert), ("exp", "embed", "ffn")),
        "w_up": P((m.num_experts, d, m.d_ff_expert), ("exp", "embed", "ffn")),
        "w_down": P((m.num_experts, m.d_ff_expert, d), ("exp", "ffn", "embed"),
                    scale=0.02 / 2),
    }
    if m.shared_ff:
        spec["shared"] = mlp_spec(d, m.shared_ff)
    return spec


def _route(router_w: jnp.ndarray, x: jnp.ndarray, k: int):
    """x (S,D) -> (weights (S,k), expert_idx (S,k), aux losses)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + z-loss
    E = router_w.shape[1]
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / max(idx.size, 1)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return vals, idx, lb, z


def _expert_ffn(buf: jnp.ndarray, wg, wu, wd) -> jnp.ndarray:
    """buf (E,C,D) -> (E,C,D), per-expert SwiGLU."""
    dt = buf.dtype
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dt))


def _dispatch_compute_combine(x_flat: jnp.ndarray, weights, idx, wg, wu, wd,
                              e_base: int, e_local: int, capacity: int):
    """Tokens (S,D) -> partial output from experts [e_base, e_base+e_local).

    Scatter tokens into an (E_local, C, D) buffer (capacity-dropping), run the
    expert FFNs, gather back weighted.  Pure local compute.
    """
    S, D = x_flat.shape
    k = idx.shape[1]
    eid = idx.reshape(-1) - e_base                            # (S*k,)
    w = weights.reshape(-1)
    local = (eid >= 0) & (eid < e_local)
    eid_c = jnp.clip(eid, 0, e_local - 1)
    # position of each assignment within its expert (stable, first-come)
    onehot = (eid_c[:, None] == jnp.arange(e_local)[None, :]) & local[:, None]
    pos = (jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1)
    pos = jnp.take_along_axis(pos, eid_c[:, None], axis=1)[:, 0]  # (S*k,)
    keep = local & (pos >= 0) & (pos < capacity)
    pos_c = jnp.clip(pos, 0, capacity - 1)
    tok = jnp.repeat(jnp.arange(S), k)
    upd = x_flat[tok] * keep[:, None].astype(x_flat.dtype)
    buf = jnp.zeros((e_local, capacity, D), x_flat.dtype)
    buf = buf.at[eid_c, pos_c].add(upd)
    out_buf = _expert_ffn(buf, wg, wu, wd)                    # (E_l, C, D)
    gathered = out_buf[eid_c, pos_c]                          # (S*k, D)
    gathered = gathered * (w * keep).astype(gathered.dtype)[:, None]
    return gathered.reshape(S, k, D).sum(axis=1)              # (S, D)


def moe_apply(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
              mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,T,D) -> (y (B,T,D), aux_loss scalar)."""
    m = cfg.moe
    B, T, D = x.shape

    if mesh is not None and "model" in mesh.axis_names:
        y, aux = _moe_shard_map(p, x, cfg, mesh)
    else:
        x_flat = x.reshape(-1, D)
        weights, idx, lb, z = _route(p["router"], x_flat, m.top_k)
        S = x_flat.shape[0]
        cap = max(int(m.top_k * S / m.num_experts * m.capacity_factor), 1)
        y = _dispatch_compute_combine(
            x_flat, weights, idx, p["w_gate"], p["w_up"], p["w_down"],
            0, m.num_experts, cap).reshape(B, T, D)
        aux = m.aux_coef * lb + m.router_z_coef * z
    if m.shared_ff:
        y = y + swiglu(x, **{k: p["shared"][k]
                             for k in ("w_gate", "w_up", "w_down")})
    return y, aux


def _moe_shard_map(p: Dict, x: jnp.ndarray, cfg: ModelConfig, mesh):
    m = cfg.moe
    B, T, D = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    ep = mesh.shape["model"]
    e_local = m.num_experts // ep
    assert e_local * ep == m.num_experts, \
        f"experts {m.num_experts} must divide model axis {ep}"
    S_local = (B // dp) * T
    cap = max(int(m.top_k * S_local / m.num_experts * m.capacity_factor), 1)

    def local_fn(xb, router, wg, wu, wd):
        # xb (B_l, T, D) — replicated over "model"; wg.. local expert slices
        xf = xb.reshape(-1, D)
        weights, idx, lb, z = _route(router, xf, m.top_k)
        e_base = jax.lax.axis_index("model") * e_local
        y_part = _dispatch_compute_combine(
            xf, weights, idx, wg, wu, wd, e_base, e_local, cap)
        y = jax.lax.psum(y_part, "model")
        aux = m.aux_coef * lb + m.router_z_coef * z
        # aux is identical across "model" ranks (routing sees the replicated
        # token block); mean over the batch axes only.
        aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(xb.shape), aux

    batch_part = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    expert_spec = PS("model", None, None)
    y, aux = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(PS(batch_part, None, None), PS(None, None),
                  expert_spec, expert_spec, expert_spec),
        out_specs=(PS(batch_part, None, None), PS()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
