"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_src, D) straight into the encoder.  The
decoder is a standard causal transformer with per-layer cross-attention to the
encoder output; decode caches both the self-attention KV ring and the
(position-independent) cross KV.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attn_decode, attn_spec, attn_train, blockwise_attention,
                        cross_attn_train, project_qkv)
from .config import ModelConfig
from .layers import P, Params, axes_tree, init_tree, mlp_spec, rms_norm, \
    stack_axes, stack_init, swiglu

COMPUTE_DTYPE = jnp.bfloat16


def enc_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": P((d,), ("embed",), init="ones"),
            "attn": attn_spec(cfg),
            "ln2": P((d,), ("embed",), init="ones"),
            "mlp": mlp_spec(d, cfg.d_ff)}


def dec_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": P((d,), ("embed",), init="ones"),
            "attn": attn_spec(cfg),
            "lnx": P((d,), ("embed",), init="ones"),
            "xattn": attn_spec(cfg),
            "ln2": P((d,), ("embed",), init="ones"),
            "mlp": mlp_spec(d, cfg.d_ff)}


def _outer_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    return {"embed": {"table": P((v, d), ("vocab", "embed"), scale=1.0)},
            "enc_norm": P((d,), ("embed",), init="ones"),
            "final_norm": P((d,), ("embed",), init="ones"),
            "head": {"w": P((d, v), ("embed", "vocab"))}}


def init_params(cfg: ModelConfig, rng) -> Params:
    r0, r1, r2 = jax.random.split(rng, 3)
    params = init_tree(r0, _outer_spec(cfg))
    params["encoder"] = stack_init(r1, enc_block_spec(cfg), cfg.enc_layers)
    params["decoder"] = stack_init(r2, dec_block_spec(cfg), cfg.n_layers)
    return params


def params_axes(cfg: ModelConfig) -> Dict[str, Any]:
    axes = axes_tree(_outer_spec(cfg))
    axes["encoder"] = stack_axes(enc_block_spec(cfg))
    axes["decoder"] = stack_axes(dec_block_spec(cfg))
    return axes


def encode(params: Params, cfg: ModelConfig, src: jnp.ndarray,
           mesh=None) -> jnp.ndarray:
    """src (B, S_src, D) precomputed frontend embeddings -> encoder states."""
    x = src.astype(COMPUTE_DTYPE)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, p):
        if cfg.shard_activations:
            from .act_sharding import constrain
            h = constrain(h, mesh, ("batch", None, None))
        h = h + attn_train(p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                           cfg, positions, causal=False, mesh=mesh)
        h = h + swiglu(rms_norm(h, p["ln2"], cfg.norm_eps), **p["mlp"])
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_fwd(p, x, enc, cfg, positions, mesh=None):
    if cfg.shard_activations:
        from .act_sharding import constrain
        x = constrain(x, mesh, ("batch", None, None))
    x = x + attn_train(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                       cfg, positions, mesh=mesh)
    x = x + cross_attn_train(p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps),
                             enc, cfg, mesh=mesh)
    x = x + swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
    return x


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            src_embeds: jnp.ndarray, mesh=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(tokens (B,S), src (B,Ssrc,D)) -> (logits (B,S,V), aux=0)."""
    enc = encode(params, cfg, src_embeds, mesh=mesh)
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(COMPUTE_DTYPE)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    step = (lambda p, h: (_dec_fwd(p, h, enc, cfg, positions, mesh), None))
    if cfg.remat:
        step = jax.checkpoint(step)
    x, _ = jax.lax.scan(lambda h, p: step(p, h), x, params["decoder"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["head"]["w"].astype(COMPUTE_DTYPE))
    return logits, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    a = cfg.attn
    L = cfg.n_layers
    kv = (L, batch, max_seq, a.n_kv_heads, a.head_dim)
    xkv = (L, batch, cfg.src_seq, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(kv, COMPUTE_DTYPE), "v": jnp.zeros(kv, COMPUTE_DTYPE),
            "xk": jnp.zeros(xkv, COMPUTE_DTYPE),
            "xv": jnp.zeros(xkv, COMPUTE_DTYPE)}


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    ax = ("layers", "batch", "seq", "kv", "hdim")
    return {"k": ax, "v": ax, "xk": ax, "xv": ax}


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            src_embeds: jnp.ndarray, mesh=None,
            cache_len: Optional[int] = None) -> Tuple[jnp.ndarray, Params]:
    enc = encode(params, cfg, src_embeds, mesh=mesh)
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(COMPUTE_DTYPE)
    S = x.shape[1]
    C = cache_len or S
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(h, p):
        if cfg.shard_activations:
            from .act_sharding import constrain
            h = constrain(h, mesh, ("batch", None, None))
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(p["attn"], hn, cfg.attn, positions)
        out = blockwise_attention(q, k, v, positions, positions, causal=True,
                                  block_kv=cfg.attn_block_kv)
        h = h + jnp.einsum("bshk,hkd->bsd", out,
                           p["attn"]["wo"].astype(h.dtype))
        h = h + cross_attn_train(p["xattn"], rms_norm(h, p["lnx"], cfg.norm_eps),
                                 enc, cfg)
        h = h + swiglu(rms_norm(h, p["ln2"], cfg.norm_eps), **p["mlp"])
        dt = COMPUTE_DTYPE
        xk = jnp.einsum("bsd,dhk->bshk", enc, p["xattn"]["wk"].astype(enc.dtype))
        xv = jnp.einsum("bsd,dhk->bshk", enc, p["xattn"]["wv"].astype(enc.dtype))
        pad = ((0, 0), (0, C - S), (0, 0), (0, 0))
        return h, (jnp.pad(k.astype(dt), pad), jnp.pad(v.astype(dt), pad),
                   xk.astype(dt), xv.astype(dt))

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["decoder"])
    x_last = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x_last,
                        params["head"]["w"].astype(COMPUTE_DTYPE))
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs}


def _cross_decode(p, x, xk, xv, cfg):
    a = cfg.attn
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    B, _, H, dh = q.shape
    KH = a.n_kv_heads
    qf = (q * (dh ** -0.5)).reshape(B, KH, H // KH, dh)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, xk.astype(dt)).astype(jnp.float32)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", w.astype(xv.dtype), xv)
    out = out.reshape(B, 1, H, dh).astype(dt)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def decode(params: Params, cfg: ModelConfig, cache: Params,
           tokens: jnp.ndarray, pos: jnp.ndarray, mesh=None
           ) -> Tuple[jnp.ndarray, Params]:
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(COMPUTE_DTYPE)

    def body(h, inp):
        p, k, v, xk, xv = inp
        y, k, v = attn_decode(p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
                              k, v, pos, cfg)
        h = h + y
        h = h + _cross_decode(p["xattn"], rms_norm(h, p["lnx"], cfg.norm_eps),
                              xk, xv, cfg)
        h = h + swiglu(rms_norm(h, p["ln2"], cfg.norm_eps), **p["mlp"])
        return h, (k, v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["head"]["w"].astype(COMPUTE_DTYPE))
    return logits, {**cache, "k": ks, "v": vs}
