"""Model configuration dataclasses for all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None       # sliding-window attention (danube)
    rope_theta: float = 10_000.0


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    chunk: int = 256                    # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_ff: int = 0                  # shared-expert FFN width (0 = none)
    every_k_layers: int = 1             # 2 = MoE every other layer (llama4)
    first_dense: int = 0                # N leading dense layers (moonshot)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense|ssm|hybrid|moe|encdec|vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnCfg] = None
    ssm: Optional[SSMCfg] = None
    moe: Optional[MoECfg] = None
    # hybrid (zamba2): one *shared* attention block applied every k SSM layers
    hybrid_share_period: int = 6
    # encoder-decoder (seamless)
    enc_layers: int = 0
    src_seq: int = 1024                 # precomputed frontend frames (stub)
    # vlm (pixtral): patch embeddings prepended to the text stream
    frontend: Optional[str] = None      # None|"audio"|"vision"
    frontend_seq: int = 0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention is computed blockwise (flash-style online softmax)
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    remat: bool = True
    # ---- §Perf hillclimb knobs (OFF = paper-faithful baseline) ----
    # store attention scores/probs in bf16 (softmax stats stay f32): halves
    # the dominant S² HBM traffic
    attn_scores_bf16: bool = False
    # shard attention activations over ("data","model") on batch when heads
    # don't divide the model axis (phi4: 24 heads vs 16) — trades one
    # activation reshard for 16x less replicated S² traffic
    attn_batch_shard: bool = False
    # pin block-boundary activation shardings (batch->(pod,data),
    # heads->model): stops GSPMD replicating S² score tensors when the GQA
    # kv dim offers no shardable axis
    shard_activations: bool = False
    # rms_norm: f32-accumulated variance + bf16 multiply (no f32 (B,S,d)
    # materialization — 6 of them per layer dominate the memory term)
    rmsnorm_bf16: bool = False
    # long-context capability: True for SSM / hybrid / SWA archs
    supports_long_context: bool = False
    # encoder-only models have no decode step
    supports_decode: bool = True

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def active_params_estimate(self) -> int:
        """~N for 6·N·D roofline math (MoE: active-expert share only)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        total = 2 * v * d  # embed + head
        if self.family in ("ssm",):
            per = self._ssm_layer_params(d)
            return total + L * per
        if self.family == "hybrid":
            per = self._ssm_layer_params(d)
            shared = self._attn_layer_params(d) + 3 * d * self.d_ff
            return total + L * per + shared
        attn = self._attn_layer_params(d) if self.attn else 0
        if self.moe:
            m = self.moe
            n_moe = (L - m.first_dense) // m.every_k_layers
            n_dense = L - n_moe
            dense_ff = 3 * d * self.d_ff
            act_ff = 3 * d * m.d_ff_expert * m.top_k + 3 * d * m.shared_ff \
                + d * m.num_experts
            return total + L * attn + n_dense * dense_ff + n_moe * act_ff
        ff = 3 * d * self.d_ff
        enc = self.enc_layers * (self._attn_layer_params(d) + ff)
        cross = self.enc_layers and L * self._attn_layer_params(d)  # decoder cross-attn
        return total + L * (attn + ff) + enc + (cross or 0)

    def total_params_estimate(self) -> int:
        if not self.moe:
            return self.active_params_estimate()
        d, L, m = self.d_model, self.n_layers, self.moe
        n_moe = (L - m.first_dense) // m.every_k_layers
        n_dense = L - n_moe
        attn = self._attn_layer_params(d)
        return (2 * self.vocab * d + L * attn + n_dense * 3 * d * self.d_ff
                + n_moe * (3 * d * m.d_ff_expert * m.num_experts
                           + 3 * d * m.shared_ff + d * m.num_experts))

    def _attn_layer_params(self, d: int) -> int:
        a = self.attn
        if a is None:
            return 0
        return d * a.head_dim * (a.n_heads * 2 + a.n_kv_heads * 2)

    def _ssm_layer_params(self, d: int) -> int:
        s = self.ssm
        di = s.d_inner(d)
        return d * di * 2 + 2 * d * s.ngroups * s.d_state + d * s.n_heads(d) \
            + di * d
