"""Model stack: configs + unified Model facade over all assigned families."""
from .config import AttnCfg, ModelConfig, MoECfg, SSMCfg
from .model import Model

__all__ = ["AttnCfg", "ModelConfig", "MoECfg", "SSMCfg", "Model"]
