"""Mamba2 (SSD — state-space duality) block: chunked train path + O(1) decode.

Training uses the SSD chunked dual form (Dao & Gu 2024, §6): the sequence is
split into chunks of length Q; within a chunk the dual quadratic (attention-
like) form runs on the MXU, across chunks the O(N) state recurrence threads
through a `lax.scan` — exactly the block-diagonal + low-rank decomposition the
paper derives.  Decode keeps (B, H, P, N) state + a (K-1)-deep conv ring.

Logical sharding: SSM heads (and therefore d_inner) shard over "model";
B/C projections (ngroups=1) are replicated, matching how Mamba2 is TP-sharded
in practice.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMCfg
from .layers import P, rms_norm

NEG_INF = -1e30


def ssm_spec(cfg: ModelConfig) -> Dict[str, P]:
    s, d = cfg.ssm, cfg.d_model
    di, H, GN = s.d_inner(d), s.n_heads(d), s.ngroups * s.d_state
    conv_dim = di + 2 * GN
    return {
        "wz": P((d, di), ("embed", "inner")),
        "wx": P((d, di), ("embed", "inner")),
        "wB": P((d, GN), ("embed", None)),
        "wC": P((d, GN), ("embed", None)),
        "wdt": P((d, H), ("embed", "heads")),
        "dt_bias": P((H,), ("heads",), init="ssm_dt"),
        "A_log": P((H,), ("heads",), init="ssm_a"),
        "D_skip": P((H,), ("heads",), init="ones"),
        "conv_w": P((s.d_conv, conv_dim), (None, "inner"), scale=0.1),
        "conv_b": P((conv_dim,), ("inner",), init="zeros"),
        "gate_norm": P((di,), ("inner",), init="ones"),
        "out_proj": P((di, d), ("inner", "embed"), scale=0.02 / 2),
    }


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv along T.  xBC (B,T,C); w (K,C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)          # (B, T+K-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i].astype(xBC.dtype)
              for i in range(K))
    return out + b.astype(xBC.dtype)


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., Q) -> (..., Q, Q): out[i,j] = sum_{k=j+1..i} a[k], -inf above diag."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    return jnp.where(i[:, None] >= i[None, :], diff, NEG_INF)


def ssd_chunked(Xdt: jnp.ndarray, A_: jnp.ndarray, Bm: jnp.ndarray,
                Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD dual form.

    Xdt (B,T,H,P) — inputs pre-multiplied by dt;  A_ (B,T,H) = dt*A (<=0);
    Bm, Cm (B,T,G,N).  Returns (Y (B,T,H,P), final_state (B,H,P,N)).
    """
    B, T, H, Pd = Xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HG = H // G
    T0 = T
    if T % chunk:  # pad tail: A_=0 (decay 1) and X=0 leave the state intact
        pad = chunk - T % chunk
        Xdt = jnp.pad(Xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A_ = jnp.pad(A_, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        T = Xdt.shape[1]
    nc = T // chunk

    # group-major reshapes: (B, nc, Q, G, HG, ...)
    Xg = Xdt.reshape(B, nc, chunk, G, HG, Pd)
    Ag = A_.reshape(B, nc, chunk, G, HG).astype(jnp.float32)
    Bg = Bm.reshape(B, nc, chunk, G, N)
    Cg = Cm.reshape(B, nc, chunk, G, N)

    cs = jnp.cumsum(Ag, axis=2)                         # (B,nc,Q,G,HG)
    # ---- intra-chunk (quadratic dual form) ----
    L = jnp.exp(_segsum(Ag.transpose(0, 1, 3, 4, 2)))   # (B,nc,G,HG,Q,Q)
    scores = jnp.einsum("bcqgn,bcpgn->bcgqp", Cg, Bg)   # (B,nc,G,Q,Q)
    Y_diag = jnp.einsum("bcgqp,bcghqp,bcpghd->bcqghd",
                        scores.astype(jnp.float32), L,
                        Xg.astype(jnp.float32))

    # ---- chunk-local end states ----
    decay_to_end = jnp.exp(cs[:, :, -1:, :, :] - cs)    # (B,nc,Q,G,HG)
    S_local = jnp.einsum("bcqgn,bcqghd,bcqgh->bcghdn",
                         Bg.astype(jnp.float32), Xg.astype(jnp.float32),
                         decay_to_end)                  # (B,nc,G,HG,P,N)

    # ---- inter-chunk recurrence (the O(N) half of the duality) ----
    chunk_decay = jnp.exp(cs[:, :, -1, :, :])           # (B,nc,G,HG)
    S0 = (jnp.zeros((B, G, HG, Pd, N), jnp.float32) if init_state is None
          else init_state.reshape(B, G, HG, Pd, N).astype(jnp.float32))

    def step(S_prev, inp):
        dec, S_loc = inp                                # (B,G,HG), (B,G,HG,P,N)
        S = S_prev * dec[..., None, None] + S_loc
        return S, S_prev                                # emit state *entering* chunk

    S_final, S_in = jax.lax.scan(
        step, S0, (chunk_decay.transpose(1, 0, 2, 3),
                   S_local.transpose(1, 0, 2, 3, 4, 5)))
    S_in = S_in.transpose(1, 0, 2, 3, 4, 5)             # (B,nc,G,HG,P,N)

    Y_off = jnp.einsum("bcqgn,bcghdn,bcqgh->bcqghd",
                       Cg.astype(jnp.float32), S_in, jnp.exp(cs))
    Y = (Y_diag + Y_off).reshape(B, T, H, Pd)[:, :T0]
    return Y.astype(Xdt.dtype), S_final.reshape(B, H, Pd, N)


def _project(p: Dict, x: jnp.ndarray, s: SSMCfg, d: int):
    dt_ = x.dtype
    z = jnp.einsum("btd,de->bte", x, p["wz"].astype(dt_))
    xs = jnp.einsum("btd,de->bte", x, p["wx"].astype(dt_))
    Bm = jnp.einsum("btd,dn->btn", x, p["wB"].astype(dt_))
    Cm = jnp.einsum("btd,dn->btn", x, p["wC"].astype(dt_))
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, p["wdt"].astype(dt_)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xs, Bm, Cm, dt


def ssm_train(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
              return_state: bool = False, mesh=None):
    """Full Mamba2 block (pre-norm happens in the caller). x (B,T,d).

    With ``return_state`` also emits (ssm_state (B,H,P,N), conv_state
    (B,K-1,C)) so prefill can hand off to the recurrent decode path.
    """
    s, d = cfg.ssm, cfg.d_model
    di, H, Pd = s.d_inner(d), s.n_heads(d), s.headdim
    G, N = s.ngroups, s.d_state
    B, T, _ = x.shape

    z, xs, Bm, Cm, dt = _project(p, x, s, d)
    if cfg.shard_activations and mesh is not None:
        from .act_sharding import constrain
        z = constrain(z, mesh, ("batch", None, "model"))
        xs = constrain(xs, mesh, ("batch", None, "model"))
        dt = constrain(dt, mesh, ("batch", None, "model"))
    xBC_pre = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC_pre, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (H,) < 0
    Xh = xs.reshape(B, T, H, Pd)
    Xdt = Xh * dt[..., None].astype(Xh.dtype)
    Y, state = ssd_chunked(Xdt, dt * A, Bm.reshape(B, T, G, N),
                           Cm.reshape(B, T, G, N), s.chunk)
    Y = Y + p["D_skip"].astype(Y.dtype)[None, None, :, None] * Xh
    y = Y.reshape(B, T, di)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        conv_state = xBC_pre[:, T - (s.d_conv - 1):, :]  # pre-activation tail
        return out, state, conv_state
    return out


# ---------------------------------------------------------------------------
# decode (recurrent form)
# ---------------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int,
                   dtype=jnp.float32):
    s, d = cfg.ssm, cfg.d_model
    di, H, Pd = s.d_inner(d), s.n_heads(d), s.headdim
    conv_dim = di + 2 * s.ngroups * s.d_state
    return {
        "ssm_state": jnp.zeros((n_layers, batch, H, Pd, s.d_state), dtype),
        "conv_state": jnp.zeros((n_layers, batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_cache_axes(_: ModelConfig):
    return {"ssm_state": ("layers", "batch", "heads", None, None),
            "conv_state": ("layers", "batch", None, "inner")}


def ssm_decode(p: Dict, x: jnp.ndarray, ssm_state: jnp.ndarray,
               conv_state: jnp.ndarray, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One token.  x (B,1,d); ssm_state (B,H,P,N); conv_state (B,K-1,C)."""
    s, d = cfg.ssm, cfg.d_model
    di, H, Pd = s.d_inner(d), s.n_heads(d), s.headdim
    G, N = s.ngroups, s.d_state
    B = x.shape[0]

    z, xs, Bm, Cm, dt = _project(p, x, s, d)
    xBC = jnp.concatenate([xs, Bm, Cm], axis=-1)        # (B,1,C)
    window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    out = sum(window[:, i] * p["conv_w"][i].astype(xBC.dtype)
              for i in range(s.d_conv))
    xBC_t = jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))  # (B,C)
    new_conv = window[:, 1:]

    xs_t, B_t, C_t = jnp.split(xBC_t, [di, di + G * N], axis=-1)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt_t = dt[:, 0]                                     # (B,H)
    dA = jnp.exp(dt_t * A)                              # (B,H)
    Xh = xs_t.reshape(B, H, Pd).astype(jnp.float32)
    Bh = jnp.repeat(B_t.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_t.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    new_state = (ssm_state.astype(jnp.float32) * dA[..., None, None]
                 + (dt_t[..., None] * Xh)[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * Xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(x.dtype))
    return out, new_state.astype(ssm_state.dtype), new_conv.astype(conv_state.dtype)
