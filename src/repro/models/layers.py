"""Shared building blocks: param specs, norms, MLP, rotary embeddings.

Params are plain nested dicts of jnp arrays.  Each module declares its
parameters as a dict of :class:`P` specs (shape + logical sharding axes +
initializer); ``init_tree`` materializes weights, ``axes_tree`` the parallel
tree of logical axes consumed by :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"     # normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(rng, p: P) -> jnp.ndarray:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "ssm_a":      # A_log init in [~log1, log16] (mamba2)
        u = jax.random.uniform(rng, p.shape, p.dtype, 1.0, 16.0)
        return jnp.log(u)
    if p.init == "ssm_dt":     # dt_bias ~ softplus^-1(U[1e-3, 1e-1])
        u = jax.random.uniform(rng, p.shape, p.dtype, 1e-3, 1e-1)
        return u + jnp.log(-jnp.expm1(-u))
    return jax.random.normal(rng, p.shape, p.dtype) * p.scale


def init_tree(rng, spec: Dict[str, Any]) -> Params:
    out: Params = {}
    keys = jax.random.split(rng, max(len(spec), 1))
    for k, (name, sub) in zip(keys, sorted(spec.items())):
        out[name] = _init_leaf(k, sub) if isinstance(sub, P) else init_tree(k, sub)
    return out


def axes_tree(spec: Dict[str, Any]) -> Dict[str, Any]:
    return {name: (sub.axes if isinstance(sub, P) else axes_tree(sub))
            for name, sub in spec.items()}


def stack_init(rng, spec: Dict[str, Any], n: int) -> Params:
    """Init n layers and stack leaves along a leading 'layers' axis (for scan)."""
    rngs = jax.random.split(rng, n)
    layers = [init_tree(r, spec) for r in rngs]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def stack_axes(spec: Dict[str, Any]) -> Dict[str, Any]:
    return jax.tree.map(lambda a: ("layers",) + a, axes_tree(spec),
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------
# §Perf iteration 4: when True, rms_norm computes the variance with an
# f32-accumulating dot and multiplies in bf16 — the f32 (B,S,d) upcast is
# never materialized (6 such tensors/layer dominated the memory term).
# Set via ModelConfig.rmsnorm_bf16 (threaded by the forward entry points).
_RMSNORM_BF16 = False


def set_rmsnorm_bf16(on: bool) -> None:
    global _RMSNORM_BF16
    _RMSNORM_BF16 = bool(on)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    if _RMSNORM_BF16 and dt != jnp.float32:
        var = jnp.einsum("...d,...d->...", x, x,
                         preferred_element_type=jnp.float32) / x.shape[-1]
        r = jax.lax.rsqrt(var + eps).astype(dt)[..., None]
        return x * r * scale.astype(dt)
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))


def mlp_spec(d: int, f: int) -> Dict[str, P]:
    return {
        "w_gate": P((d, f), ("embed", "ffn")),
        "w_up": P((d, f), ("embed", "ffn")),
        "w_down": P((f, d), ("ffn", "embed"), scale=0.02 / 2),
    }


def rope(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) int -> cos/sin (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., n_heads, head_dim); cos/sin broadcastable to (..., 1, hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token CE.  logits (B,S,V) — may be vocab-sharded; the lse
    reduction lowers to a sharded reduce."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()
