"""Decoder-only LM assembly for the dense / moe / ssm / hybrid families.

Layers are *scanned* (params stacked on a leading "layers" axis) so the HLO
stays compact at 64 layers × 512 devices, with optional per-block remat.
Three entry points per family: ``forward`` (training logits), ``prefill``
(logits + cache), ``decode`` (one token with cache).

Family structure:
  dense    scan L × [attn, mlp]
  moe      every_k_layers=2 → scan L/2 × [dense-block, moe-block] (llama4)
           first_dense=n    → n unscanned dense + scan (L-n) × moe-block
  ssm      scan L × [mamba2]
  hybrid   scan G groups × [period × mamba2 + one SHARED attn block]
           (zamba2: the attention block's params are shared across groups)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attn_decode, attn_spec, attn_train, init_kv_cache,
                        kv_cache_axes)
from .config import ModelConfig
from .layers import (P, Params, axes_tree, init_tree, mlp_spec, rms_norm,
                     stack_axes, stack_init, swiglu)
from .moe import moe_apply, moe_spec
from .ssm import (init_ssm_cache, ssm_cache_axes, ssm_decode, ssm_spec,
                  ssm_train)

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# block specs
# ---------------------------------------------------------------------------
def dense_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": P((d,), ("embed",), init="ones"),
            "attn": attn_spec(cfg),
            "ln2": P((d,), ("embed",), init="ones"),
            "mlp": mlp_spec(d, cfg.d_ff)}


def moe_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"ln1": P((d,), ("embed",), init="ones"),
            "attn": attn_spec(cfg),
            "ln2": P((d,), ("embed",), init="ones"),
            "moe": moe_spec(cfg)}


def ssm_block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {"norm": P((d,), ("embed",), init="ones"),
            "ssm": ssm_spec(cfg)}


def _outer_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    return {
        "embed": {"table": P((v, d), ("vocab", "embed"), scale=1.0)},
        "final_norm": P((d,), ("embed",), init="ones"),
        "head": {"w": P((d, v), ("embed", "vocab"))},
    }


def _moe_layout(cfg: ModelConfig) -> Tuple[int, int]:
    m = cfg.moe
    n_moe = (cfg.n_layers - m.first_dense) // m.every_k_layers
    return m.first_dense, n_moe


def init_params(cfg: ModelConfig, rng) -> Params:
    r_out, r_blocks, r_extra = jax.random.split(rng, 3)
    params = init_tree(r_out, _outer_spec(cfg))
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = stack_init(r_blocks, dense_block_spec(cfg), cfg.n_layers)
    elif fam == "ssm":
        params["blocks"] = stack_init(r_blocks, ssm_block_spec(cfg), cfg.n_layers)
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.hybrid_share_period
        flat = stack_init(r_blocks, ssm_block_spec(cfg), cfg.n_layers)
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape(g, cfg.hybrid_share_period, *x.shape[1:]), flat)
        params["shared_attn"] = init_tree(r_extra, dense_block_spec(cfg))
    elif fam == "moe":
        first_dense, n_moe = _moe_layout(cfg)
        if cfg.moe.every_k_layers == 2:
            params["blocks"] = stack_init(
                r_blocks, {"dense": dense_block_spec(cfg),
                           "moe": moe_block_spec(cfg)}, cfg.n_layers // 2)
        else:
            if first_dense:
                params["first"] = stack_init(r_extra, dense_block_spec(cfg),
                                             first_dense)
            params["blocks"] = stack_init(r_blocks, moe_block_spec(cfg), n_moe)
    else:
        raise ValueError(fam)
    return params


def params_axes(cfg: ModelConfig) -> Dict[str, Any]:
    axes = axes_tree(_outer_spec(cfg))
    fam = cfg.family
    if fam in ("dense", "vlm"):
        axes["blocks"] = stack_axes(dense_block_spec(cfg))
    elif fam == "ssm":
        axes["blocks"] = stack_axes(ssm_block_spec(cfg))
    elif fam == "hybrid":
        axes["blocks"] = jax.tree.map(
            lambda a: ("layers",) + a, stack_axes(ssm_block_spec(cfg)),
            is_leaf=lambda x: isinstance(x, tuple))
        axes["shared_attn"] = axes_tree(dense_block_spec(cfg))
    elif fam == "moe":
        first_dense, _ = _moe_layout(cfg)
        if cfg.moe.every_k_layers == 2:
            axes["blocks"] = stack_axes({"dense": dense_block_spec(cfg),
                                         "moe": moe_block_spec(cfg)})
        else:
            if first_dense:
                axes["first"] = stack_axes(dense_block_spec(cfg))
            axes["blocks"] = stack_axes(moe_block_spec(cfg))
    return axes


# ---------------------------------------------------------------------------
# block forward fns (training): (params, x, positions) -> (x, aux)
# ---------------------------------------------------------------------------
def _dense_fwd(p, x, cfg, positions, mesh=None):
    if cfg.shard_activations:
        from .act_sharding import constrain
        x = constrain(x, mesh, ("batch", None, None))
    x = x + attn_train(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                       positions, mesh=mesh)
    x = x + swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
    return x, jnp.float32(0.0)


def _moe_fwd(p, x, cfg, positions, mesh):
    if cfg.shard_activations:
        from .act_sharding import constrain
        x = constrain(x, mesh, ("batch", None, None))
    x = x + attn_train(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
                       positions, mesh=mesh)
    y, aux = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, mesh)
    return x + y, aux


def _ssm_fwd(p, x, cfg, mesh=None):
    if cfg.shard_activations:
        from .act_sharding import constrain
        x = constrain(x, mesh, ("batch", None, None))
    return x + ssm_train(p["ssm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg,
                         mesh=mesh), jnp.float32(0.0)


def _scan(step, params_stacked, x, remat: bool):
    f = jax.checkpoint(step) if remat else step

    def body(carry, layer_p):
        h, aux = carry
        h, a = f(layer_p, h)
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params_stacked)
    return x, aux


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None,
            mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S) -> (logits (B,S_total,V) bf16, aux_loss)."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(COMPUTE_DTYPE)
    if extra_embeds is not None:   # vlm/audio stub frontends prepend embeddings
        x = jnp.concatenate([extra_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        x, aux = _scan(lambda p, h: _dense_fwd(p, h, cfg, positions, mesh),
                       params["blocks"], x, cfg.remat)
    elif fam == "ssm":
        x, aux = _scan(lambda p, h: _ssm_fwd(p, h, cfg, mesh),
                       params["blocks"], x, cfg.remat)
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group_step(p, h):
            h, a = _scan(lambda q, g: _ssm_fwd(q, g, cfg, mesh), p, h, False)
            h, a2 = _dense_fwd(shared, h, cfg, positions, mesh)
            return h, a + a2

        x, aux = _scan(group_step, params["blocks"], x, cfg.remat)
    elif fam == "moe":
        first_dense, _ = _moe_layout(cfg)
        aux = jnp.float32(0.0)
        if cfg.moe.every_k_layers == 2:
            def pair_step(p, h):
                h, _ = _dense_fwd(p["dense"], h, cfg, positions, mesh)
                return _moe_fwd(p["moe"], h, cfg, positions, mesh)
            x, aux = _scan(pair_step, params["blocks"], x, cfg.remat)
        else:
            if first_dense:
                x, _ = _scan(lambda p, h: _dense_fwd(p, h, cfg, positions,
                                                     mesh),
                             params["first"], x, cfg.remat)
            x, aux = _scan(lambda p, h: _moe_fwd(p, h, cfg, positions, mesh),
                           params["blocks"], x, cfg.remat)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["head"]["w"].astype(COMPUTE_DTYPE))
    return logits, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return init_kv_cache(cfg, batch, max_seq, cfg.n_layers)
    if fam == "ssm":
        return init_ssm_cache(cfg, batch, cfg.n_layers)
    if fam == "hybrid":
        g = cfg.n_layers // cfg.hybrid_share_period
        ssm = init_ssm_cache(cfg, batch, cfg.n_layers)
        ssm = jax.tree.map(
            lambda x: x.reshape(g, cfg.hybrid_share_period, *x.shape[1:]), ssm)
        kv = init_kv_cache(cfg, batch, max_seq, g)
        return {**ssm, **kv}
    if fam == "moe":
        first_dense, n_moe = _moe_layout(cfg)
        if cfg.moe.every_k_layers == 2:
            kv = init_kv_cache(cfg, batch, max_seq, cfg.n_layers // 2)
            return {"k": jnp.stack([kv["k"], kv["k"]], 1),
                    "v": jnp.stack([kv["v"], kv["v"]], 1)}
        out = init_kv_cache(cfg, batch, max_seq, n_moe)
        if first_dense:
            fkv = init_kv_cache(cfg, batch, max_seq, first_dense)
            out = {**out, "k_first": fkv["k"], "v_first": fkv["v"]}
        return out
    raise ValueError(fam)


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    fam = cfg.family
    kv_ax = kv_cache_axes(cfg)
    if fam in ("dense", "vlm"):
        return kv_ax
    if fam == "ssm":
        return ssm_cache_axes(cfg)
    if fam == "hybrid":
        ssm_ax = {k: ("layers",) + v for k, v in ssm_cache_axes(cfg).items()}
        return {**ssm_ax, **kv_ax}
    if fam == "moe":
        first_dense, _ = _moe_layout(cfg)
        if cfg.moe.every_k_layers == 2:
            ax = ("layers", None, "batch", "seq", "kv", "hdim")
            return {"k": ax, "v": ax}
        out = dict(kv_ax)
        if first_dense:
            out["k_first"] = kv_ax["k"]
            out["v_first"] = kv_ax["v"]
        return out
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# prefill: forward pass that also materializes the decode caches.
# Logits are computed for the LAST position only (a (B,S,V) logits tensor at
# 32k prefill would be hundreds of GB).
# ---------------------------------------------------------------------------
def _to_kv_cache(k: jnp.ndarray, C: int) -> jnp.ndarray:
    """k (B,S,KH,dh) -> cache (B,C,KH,dh); ring-rolled when C < S (SWA)."""
    B, S = k.shape[0], k.shape[1]
    k = k.astype(COMPUTE_DTYPE)
    if C >= S:
        return jnp.pad(k, ((0, 0), (0, C - S), (0, 0), (0, 0)))
    last = k[:, S - C:]
    return jnp.roll(last, shift=(S - C) % C, axis=1)


def _dense_prefill(p, x, cfg, positions, C, mesh=None):
    from .attention import _maybe_shard_q, blockwise_attention, project_qkv
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(p["attn"], h, cfg.attn, positions)
    if cfg.shard_activations:
        from .act_sharding import constrain
        q = constrain(q, mesh, ("batch", None, "model", None))
        k = constrain(k, mesh, ("batch", None, "model", None))
        v = constrain(v, mesh, ("batch", None, "model", None))
    q = _maybe_shard_q(q, cfg, mesh)
    out = blockwise_attention(q, k, v, positions, positions, causal=True,
                              window=cfg.attn.window,
                              block_kv=cfg.attn_block_kv,
                              scores_bf16=cfg.attn_scores_bf16)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(x.dtype))
    x = x + swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
    return x, _to_kv_cache(k, C), _to_kv_cache(v, C)


def _moe_prefill(p, x, cfg, positions, C, mesh):
    from .attention import _maybe_shard_q, blockwise_attention, project_qkv
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = project_qkv(p["attn"], h, cfg.attn, positions)
    if cfg.shard_activations:
        from .act_sharding import constrain
        q = constrain(q, mesh, ("batch", None, "model", None))
        k = constrain(k, mesh, ("batch", None, "model", None))
        v = constrain(v, mesh, ("batch", None, "model", None))
    q = _maybe_shard_q(q, cfg, mesh)
    out = blockwise_attention(q, k, v, positions, positions, causal=True,
                              window=cfg.attn.window,
                              block_kv=cfg.attn_block_kv,
                              scores_bf16=cfg.attn_scores_bf16)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(x.dtype))
    y, _ = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, mesh)
    return x + y, _to_kv_cache(k, C), _to_kv_cache(v, C)


def _ssm_prefill(p, x, cfg, mesh=None):
    if cfg.shard_activations:
        from .act_sharding import constrain
        x = constrain(x, mesh, ("batch", None, None))
    y, st, cv = ssm_train(p["ssm"], rms_norm(x, p["norm"], cfg.norm_eps),
                          cfg, return_state=True, mesh=mesh)
    return x + y, st, cv


def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None,
            mesh=None, cache_len: Optional[int] = None
            ) -> Tuple[jnp.ndarray, Params]:
    """tokens (B,S) -> (last-position logits (B,1,V), cache)."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(COMPUTE_DTYPE)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    a = cfg.attn
    C = cache_len or S
    if a is not None and a.window:
        C = min(C, a.window)
    fam = cfg.family
    cache: Dict[str, Any] = {}

    if fam in ("dense", "vlm"):
        def body(h, p):
            h, kc, vc = _dense_prefill(p, h, cfg, positions, C, mesh)
            return h, (kc, vc)
        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache = {"k": ks, "v": vs}
    elif fam == "ssm":
        def body(h, p):
            h, st, cv = _ssm_prefill(p, h, cfg, mesh)
            return h, (st, cv)
        x, (sts, cvs) = jax.lax.scan(body, x, params["blocks"])
        cache = {"ssm_state": sts, "conv_state": cvs}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, p):
            def inner(hh, q):
                hh, st, cv = _ssm_prefill(q, hh, cfg, mesh)
                return hh, (st, cv)
            h, (st, cv) = jax.lax.scan(inner, h, p)
            h, kc, vc = _dense_prefill(shared, h, cfg, positions, C, mesh)
            return h, (st, cv, kc, vc)
        x, (sts, cvs, ks, vs) = jax.lax.scan(group, x, params["blocks"])
        cache = {"ssm_state": sts, "conv_state": cvs, "k": ks, "v": vs}
    elif fam == "moe":
        first_dense, _ = _moe_layout(cfg)
        if cfg.moe.every_k_layers == 2:
            def pair(h, p):
                h, kd, vd = _dense_prefill(p["dense"], h, cfg, positions, C,
                                           mesh)
                h, km, vm = _moe_prefill(p["moe"], h, cfg, positions, C, mesh)
                return h, (jnp.stack([kd, km]), jnp.stack([vd, vm]))
            x, (ks, vs) = jax.lax.scan(pair, x, params["blocks"])
            cache = {"k": ks, "v": vs}
        else:
            if first_dense:
                def fbody(h, p):
                    h, kc, vc = _dense_prefill(p, h, cfg, positions, C, mesh)
                    return h, (kc, vc)
                x, (kf, vf) = jax.lax.scan(fbody, x, params["first"])
                cache["k_first"], cache["v_first"] = kf, vf

            def mbody(h, p):
                h, kc, vc = _moe_prefill(p, h, cfg, positions, C, mesh)
                return h, (kc, vc)
            x, (ks, vs) = jax.lax.scan(mbody, x, params["blocks"])
            cache["k"], cache["v"] = ks, vs
    else:
        raise ValueError(fam)

    x_last = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x_last,
                        params["head"]["w"].astype(COMPUTE_DTYPE))
    return logits, cache


# ---------------------------------------------------------------------------
# decode: one token through all layers, caches scanned alongside params
# ---------------------------------------------------------------------------
def _dense_dec(p, x, k, v, pos, cfg):
    y, k, v = attn_decode(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                          k, v, pos, cfg)
    x = x + y
    x = x + swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
    return x, k, v


def _moe_dec(p, x, k, v, pos, cfg, mesh):
    y, k, v = attn_decode(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                          k, v, pos, cfg)
    x = x + y
    y, _ = moe_apply(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg, mesh)
    return x + y, k, v


def _ssm_dec(p, x, st, cv, cfg):
    y, st, cv = ssm_decode(p["ssm"], rms_norm(x, p["norm"], cfg.norm_eps),
                           st, cv, cfg)
    return x + y, st, cv


def decode(params: Params, cfg: ModelConfig, cache: Params,
           tokens: jnp.ndarray, pos: jnp.ndarray,
           mesh=None) -> Tuple[jnp.ndarray, Params]:
    """tokens (B,1) int32, pos scalar int32 -> (logits (B,1,V), new cache)."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(COMPUTE_DTYPE)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "vlm"):
        def body(h, inp):
            p, k, v = inp
            h, k, v = _dense_dec(p, h, k, v, pos, cfg)
            return h, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    elif fam == "ssm":
        def body(h, inp):
            p, st, cv = inp
            h, st, cv = _ssm_dec(p, h, st, cv, cfg)
            return h, (st, cv)
        x, (sts, cvs) = jax.lax.scan(
            body, x, (params["blocks"], cache["ssm_state"], cache["conv_state"]))
        new_cache = {"ssm_state": sts, "conv_state": cvs}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group(h, inp):
            p, st, cv, k, v = inp

            def inner(hh, ii):
                q, s2, c2 = ii
                hh, s2, c2 = _ssm_dec(q, hh, s2, c2, cfg)
                return hh, (s2, c2)
            h, (st, cv) = jax.lax.scan(inner, h, (p, st, cv))
            h, k, v = _dense_dec(shared, h, k, v, pos, cfg)
            return h, (st, cv, k, v)

        x, (sts, cvs, ks, vs) = jax.lax.scan(
            group, x, (params["blocks"], cache["ssm_state"],
                       cache["conv_state"], cache["k"], cache["v"]))
        new_cache = {"ssm_state": sts, "conv_state": cvs, "k": ks, "v": vs}
    elif fam == "moe":
        first_dense, _ = _moe_layout(cfg)
        if cfg.moe.every_k_layers == 2:
            def pair(h, inp):
                p, k2, v2 = inp
                h, kd, vd = _dense_dec(p["dense"], h, k2[0], v2[0], pos, cfg)
                h, km, vm = _moe_dec(p["moe"], h, k2[1], v2[1], pos, cfg, mesh)
                return h, (jnp.stack([kd, km]), jnp.stack([vd, vm]))
            x, (ks, vs) = jax.lax.scan(pair, x, (params["blocks"],
                                                 cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs}
        else:
            new_cache = dict(cache)
            if first_dense:
                def fbody(h, inp):
                    p, k, v = inp
                    h, k, v = _dense_dec(p, h, k, v, pos, cfg)
                    return h, (k, v)
                x, (kf, vf) = jax.lax.scan(
                    fbody, x, (params["first"], cache["k_first"],
                               cache["v_first"]))
                new_cache["k_first"], new_cache["v_first"] = kf, vf

            def mbody(h, inp):
                p, k, v = inp
                h, k, v = _moe_dec(p, h, k, v, pos, cfg, mesh)
                return h, (k, v)
            x, (ks, vs) = jax.lax.scan(mbody, x, (params["blocks"],
                                                  cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = ks, vs
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["head"]["w"].astype(COMPUTE_DTYPE))
    return logits, new_cache
