"""Pallas TPU kernels for the TPQ decode hot path.

Each kernel module holds a ``pl.pallas_call`` with explicit BlockSpec VMEM
tiling; :mod:`.ops` has the jit'd wrappers; :mod:`.ref` the pure-jnp oracles
the tests sweep against.
"""
from .ops import (bitunpack, bss_decode, decode_on_device, delta_decode,
                  dict_decode, filter_range, page_minmax)

__all__ = ["bitunpack", "bss_decode", "decode_on_device", "delta_decode",
           "dict_decode", "filter_range", "page_minmax"]
