"""Pallas TPU kernel: BYTE_STREAM_SPLIT decode for float32 columns.

BSS stores the i-th byte of every value contiguously (great for compression);
decode recombines four byte planes into IEEE words.  On TPU this is four
widening loads + shifts + ors on the VPU and one bitcast — no transpose
through HBM: the four planes stream block-by-block into VMEM and recombine
in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.lax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _bss_kernel(planes_ref, out_ref):
    b = planes_ref[...].astype(jnp.uint32)         # (4, B)
    word = b[0] | (b[1] << 8) | (b[2] << 16) | (b[3] << 24)
    out_ref[...] = jax.lax.bitcast_convert_type(word, jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bss_decode(byte_planes: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """byte_planes: (4, n) uint8 -> (n,) float32."""
    assert byte_planes.shape[0] == 4, "float32 has 4 byte planes"
    n = byte_planes.shape[1]
    if n == 0:
        return jnp.zeros(0, jnp.float32)
    blocks = -(-n // BLOCK)
    planes = jnp.pad(byte_planes, ((0, 0), (0, blocks * BLOCK - n)))
    out = pl.pallas_call(
        _bss_kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((4, BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((blocks * BLOCK,), jnp.float32),
        interpret=interpret,
    )(planes)
    return out[:n]
