"""Pallas TPU kernel: fused per-page min/max statistics (the write path).

When device-resident data is written back into the columnar store (e.g. the
checkpoint-as-database path), page statistics have to be computed before
encoding.  This kernel reduces each page to (min, max) in one VMEM pass —
the footer statistics the reader later prunes on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(x_ref, min_ref, max_ref):
    x = x_ref[...]
    min_ref[0] = x.min()
    max_ref[0] = x.max()


@functools.partial(jax.jit, static_argnames=("page", "interpret"))
def page_minmax(x: jnp.ndarray, page: int, *, interpret: bool = True):
    """Per-page (min, max); n must be padded to a multiple of ``page``."""
    n = x.shape[0]
    pages = -(-n // page)
    if pages * page != n:
        # pad with the last element so stats are unaffected
        x = jnp.concatenate([x, jnp.full(pages * page - n, x[-1], x.dtype)])
    mins, maxs = pl.pallas_call(
        _stats_kernel,
        grid=(pages,),
        in_specs=[pl.BlockSpec((page,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((pages,), x.dtype),
                   jax.ShapeDtypeStruct((pages,), x.dtype)],
        interpret=interpret,
    )(x)
    return mins, maxs
