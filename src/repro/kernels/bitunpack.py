"""Pallas TPU kernel: k-bit unpack (the BITPACK/DICT-index decode hot path).

Hardware adaptation (DESIGN.md §2): the paper decodes on the host CPU; here the
host ships the *packed* stream (k/32 of the decoded size) over PCIe and the
chip widens it in VMEM next to the consumer.

TPU-native formulation: a gather-free bit expansion.  A block of W uint32
words is broadcast against the 32 bit positions (VPU-friendly compare/shift
ops, no dynamic indexing), giving a (W, 32) bit matrix that reshapes to
(L, k) with L = 32*W/k, then contracts against the k powers of two.  The
reshape is exact because blocks are chosen with L*k % 32 == 0, so values never
straddle a block boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Each grid step decodes LANE_VALUES outputs. 1024 int32 outputs = 4 KiB out,
# k*128 bytes in — comfortably inside VMEM with room for double buffering.
LANE_VALUES = 1024


def _bitunpack_kernel(words_ref, out_ref, *, k: int):
    w = words_ref[...].astype(jnp.uint32)                      # (W,)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (w[:, None] >> shifts[None, :]) & jnp.uint32(1)     # (W, 32)
    vals = bits.reshape(-1, k)                                 # (L, k) exact
    powers = (jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32))
    out_ref[...] = (vals * powers[None, :]).sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n", "k", "interpret"))
def bitunpack(words: jnp.ndarray, n: int, k: int, *,
              interpret: bool = True) -> jnp.ndarray:
    """Decode ``n`` k-bit values from a packed little-endian uint32 stream."""
    if k == 0:
        return jnp.zeros(n, jnp.int32)
    if k > 32:
        raise ValueError("device bitunpack supports k <= 32")
    L = LANE_VALUES
    # W words per block; L*k must be a multiple of 32 (it is: L=1024)
    W = (L * k) // 32
    blocks = -(-n // L)
    need_words = blocks * W
    words = words.astype(jnp.uint32)
    pad = need_words - words.shape[0]
    if pad > 0:
        words = jnp.pad(words, (0, pad))
    out = pl.pallas_call(
        functools.partial(_bitunpack_kernel, k=k),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((W,), lambda i: (i,))],
        out_specs=pl.BlockSpec((L,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((blocks * L,), jnp.int32),
        interpret=interpret,
    )(words)
    return out[:n]
