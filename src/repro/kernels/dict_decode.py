"""Pallas TPU kernel: dictionary decode (DICT-encoded column hot path).

TPU adaptation: instead of a scalar gather (cheap on CPU, serialized on TPU),
small dictionaries are decoded as a *one-hot contraction*: the (L, D) match
matrix against the D dictionary entries is an MXU-shaped operation.  The full
dictionary lives in VMEM and is re-used by every grid step (its BlockSpec
index map pins block 0).  For D > MAX_ONEHOT_DICT the jit'd wrapper falls
back to ``jnp.take`` outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
MAX_ONEHOT_DICT = 4096  # one-hot beyond this wastes FLOPs vs a gather


def _dict_kernel(idx_ref, dict_ref, out_ref):
    idx = idx_ref[...].astype(jnp.int32)                       # (L,)
    d = dict_ref[...]                                          # (D,)
    iota = jnp.arange(d.shape[0], dtype=jnp.int32)
    onehot = (idx[:, None] == iota[None, :])                   # (L, D)
    if jnp.issubdtype(d.dtype, jnp.floating):
        out = jnp.dot(onehot.astype(d.dtype), d)               # MXU path
    else:
        out = jnp.where(onehot, d[None, :], 0).sum(axis=1).astype(d.dtype)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("interpret",))
def dict_decode(indices: jnp.ndarray, dictionary: jnp.ndarray, *,
                interpret: bool = True) -> jnp.ndarray:
    """out[i] = dictionary[indices[i]]."""
    n, d = indices.shape[0], dictionary.shape[0]
    if d > MAX_ONEHOT_DICT or n == 0:
        return jnp.take(dictionary, indices.astype(jnp.int32), axis=0)
    blocks = -(-n // BLOCK)
    idx = jnp.pad(indices.astype(jnp.int32), (0, blocks * BLOCK - n))
    out = pl.pallas_call(
        _dict_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),  # whole dict resident in VMEM
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((blocks * BLOCK,), dictionary.dtype),
        interpret=interpret,
    )(idx, dictionary)
    return out[:n]
