"""Pallas TPU kernel: DELTA decode (zigzag + running prefix sum).

The sequential dependency (a cumulative sum over the whole column) maps onto
the TPU's *sequential grid*: each grid step computes the inclusive cumsum of
its block in VMEM and threads the running total to the next step through an
SMEM scratch cell — the same carry idiom TPU matmul kernels use for
accumulators.  No second pass and no host round-trip.

Input convention (matches ``repro.core.encodings._enc_delta``): ``zz`` holds
zigzag-encoded deltas with a leading 0 slot, so ``out = first + cumsum(deltas)``
has length n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048


def _unzigzag(u: jnp.ndarray) -> jnp.ndarray:
    u = u.astype(jnp.uint32)
    neg = -(u & jnp.uint32(1)).astype(jnp.int32)
    return ((u >> jnp.uint32(1)) ^ neg.astype(jnp.uint32)).astype(jnp.int32)


def _delta_kernel(zz_ref, first_ref, out_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = first_ref[0]

    deltas = _unzigzag(zz_ref[...])                 # (B,)
    csum = jnp.cumsum(deltas, dtype=jnp.int32)      # in-VMEM scan
    out_ref[...] = carry_ref[0] + csum
    carry_ref[0] = carry_ref[0] + csum[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_decode(zz: jnp.ndarray, first: jnp.ndarray, *,
                 interpret: bool = True) -> jnp.ndarray:
    n = zz.shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32)
    blocks = -(-n // BLOCK)
    zzp = jnp.pad(zz.astype(jnp.uint32), (0, blocks * BLOCK - n))
    out = pl.pallas_call(
        _delta_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scalar `first`
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((blocks * BLOCK,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(zzp, first.astype(jnp.int32).reshape(1))
    return out[:n]
