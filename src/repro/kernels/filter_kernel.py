"""Pallas TPU kernel: fused range-predicate evaluation + per-block match count.

The device-side half of predicate pushdown: after a column block is decoded in
VMEM, the predicate ``lo <= x <= hi`` is evaluated *in the same memory space*
and a per-block match count is emitted so the consumer can skip empty blocks
without reading the mask back — mirroring how the host-side reader skips pages
by their footer statistics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 2048


def _filter_kernel(bounds_ref, x_ref, mask_ref, count_ref):
    x = x_ref[...]
    lo = bounds_ref[0].astype(x.dtype)
    hi = bounds_ref[1].astype(x.dtype)
    m = (x >= lo) & (x <= hi)
    mask_ref[...] = m
    count_ref[0] = m.sum(dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def filter_range(x: jnp.ndarray, lo, hi, *, interpret: bool = True):
    """Returns (mask: bool (n,), block_counts: int32 (blocks,))."""
    n = x.shape[0]
    blocks = max(-(-n // BLOCK), 1)
    # pad with a value outside [lo, hi]? — padding contributes False because
    # we pad with lo-1 when integral, else -inf
    if jnp.issubdtype(x.dtype, jnp.floating):
        fill = jnp.array(-jnp.inf, x.dtype)
    else:
        fill = jnp.asarray(lo, x.dtype) - 1
    xp = jnp.full((blocks * BLOCK,), fill, x.dtype).at[:n].set(x)
    bounds = jnp.stack([jnp.asarray(lo, jnp.float32),
                        jnp.asarray(hi, jnp.float32)])
    mask, counts = pl.pallas_call(
        _filter_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # (2,) bounds
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * BLOCK,), jnp.bool_),
            jax.ShapeDtypeStruct((blocks,), jnp.int32),
        ],
        interpret=interpret,
    )(bounds, xp)
    return mask[:n], counts
