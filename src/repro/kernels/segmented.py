"""Segmented (multi-page) decode kernels: ONE device dispatch per morsel.

The per-page kernels in :mod:`bitunpack` / :mod:`dict_decode` /
:mod:`delta_decode` cost one Python-level ``pallas_call`` per page — which is
exactly the GIL convoy the parallel scan measures (bench/BENCH_fig11.json).
Here a whole morsel's pages of one column chunk are decoded by a single
fused dispatch:

- the host concatenates the packed page payloads 4-byte-aligned and
  precomputes, per output element, the 32-bit word index / shift / mask of
  its packed value (pure numpy index arithmetic, no data-dependent work);
- the device gathers the two straddling words (XLA gather — dynamic
  indexing is the one thing Pallas TPU blocks can't do), then a Pallas
  kernel fuses the shift/or/mask/reference-add combine over VPU lanes;
- DICT gathers one concatenated dictionary, DELTA recovers values with a
  single cumulative sum over all pages (page-start slots carry zero, so
  ``c[i] - c[start(p)] + first[p]`` is the page-local prefix sum — int32
  wrap commutes with the subtraction, and the backend's 32-bit gate proves
  every *final* value fits, so wrapped intermediates are still exact).

All functions take pre-staged host arrays from :func:`plan_segments` and are
jit'd on shape: inputs are padded to power-of-two lengths so repeated morsel
shapes hit the trace cache.  ``interpret`` defaults True off-TPU.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["plan_segments", "seg_bitunpack", "seg_dict_decode",
           "seg_delta_decode"]

LANE_VALUES = 1024  # outputs per Pallas grid step (matches bitunpack.py)


# ---------------------------------------------------------------------------
# host-side staging (numpy; no data-dependent work, just index arithmetic)
# ---------------------------------------------------------------------------
def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def plan_segments(payloads: Sequence, ns: np.ndarray, ks: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stage a morsel's packed pages for one fused device dispatch.

    Returns ``(words, w0, sh, mask)``: the 4-byte-aligned concatenated
    uint32 word stream plus, per output element, the word index of its
    value's low word, the in-word bit shift and the k-bit mask.  Element
    *i* of page *p* (packed at ``ks[p] <= 31`` bits) lives at bit
    ``base[p] + i * ks[p]`` and spans at most two uint32 words.  Arrays
    are padded to power-of-two lengths (padding decodes word 0 harmlessly)
    so repeated morsel shapes reuse the jit trace.
    """
    total = int(ns.sum())
    needs = [(int(n) * int(k) + 7) // 8 for n, k in zip(ns, ks)]
    bases = np.zeros(len(payloads), np.int64)
    off = 0
    for p, nb in enumerate(needs):
        bases[p] = off
        off += (nb + 3) // 4 * 4
    buf = np.zeros(_pow2(off + 8), np.uint8)
    for base, pl_, nb in zip(bases, payloads, needs):
        if nb:
            buf[base:base + nb] = np.frombuffer(pl_, np.uint8, count=nb)
    words = buf.view("<u4")
    pid = np.repeat(np.arange(len(ns)), ns)
    starts = np.zeros(len(ns), np.int64)
    np.cumsum(ns[:-1], out=starts[1:])
    idx = np.arange(total, dtype=np.int64) - np.repeat(starts, ns)
    bit = bases[pid] * 8 + idx * ks[pid]
    pad = _pow2(total)
    w0 = np.zeros(pad, np.int32)
    sh = np.zeros(pad, np.uint32)
    mask = np.zeros(pad, np.uint32)
    w0[:total] = bit >> 5
    sh[:total] = bit & 31
    mask[:total] = ((np.uint32(1) << ks.astype(np.uint32))
                    - np.uint32(1))[pid]
    return words, w0, sh, mask


# ---------------------------------------------------------------------------
# device kernels
# ---------------------------------------------------------------------------
def _combine_kernel(lo_ref, hi_ref, mask_ref, ref_ref, out_ref):
    """Fused shift-merge + mask + reference-add over one lane block."""
    v = (lo_ref[...] | hi_ref[...]) & mask_ref[...]
    out_ref[...] = v.astype(jnp.int32) + ref_ref[...]


def _combine(lo, hi, mask, refs, interpret: bool) -> jnp.ndarray:
    n = lo.shape[0]  # static under jit; already power-of-two padded
    blocks = -(-n // LANE_VALUES)
    spec = pl.BlockSpec((LANE_VALUES,), lambda i: (i,))
    return pl.pallas_call(
        _combine_kernel,
        grid=(blocks,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((blocks * LANE_VALUES,), jnp.int32),
        interpret=interpret,
    )(lo, hi, mask, refs)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _seg_values(words, w0, sh, mask, refs, *, interpret: bool = True):
    """Gather + combine: the packed-value stream of a whole morsel."""
    w = words.astype(jnp.uint32)
    lo = w[w0] >> sh
    hi = jnp.where(sh == 0, jnp.uint32(0),
                   w[w0 + 1] << ((jnp.uint32(32) - sh) & jnp.uint32(31)))
    return _combine(lo, hi, mask, refs, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def seg_bitunpack(words, w0, sh, mask, refs, *, interpret: bool = True
                  ) -> jnp.ndarray:
    """BITPACK a whole morsel: unpack + frame-of-reference add, one dispatch.

    ``refs`` is the per-element reference (int32, page-constant).
    """
    return _seg_values(words, w0, sh, mask, refs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def seg_dict_decode(words, w0, sh, mask, dictionary, doff, *,
                    interpret: bool = True) -> jnp.ndarray:
    """DICT a whole morsel: one index unpack + one gather of the
    concatenated per-page dictionaries (``doff`` = per-element dict base)."""
    idx = _seg_values(words, w0, sh, mask, jnp.zeros_like(w0),
                      interpret=interpret)
    return dictionary[idx + doff]


@functools.partial(jax.jit, static_argnames=("interpret",))
def seg_delta_decode(words, w0, sh, mask, dpos, starts, pid, firsts, n, *,
                     interpret: bool = True) -> jnp.ndarray:
    """DELTA a whole morsel: one zigzag unpack + ONE global cumsum.

    ``dpos`` scatters each decoded delta to its output slot (page-start
    slots stay zero), ``starts``/``pid``/``firsts`` recover page-local
    prefix sums from the global cumulative sum.  ``n`` is a length-1 array
    carrying the unpadded element count (kept as data, not a static arg,
    so shape buckets share one trace).
    """
    zz = _seg_values(words, w0, sh, mask, jnp.zeros_like(w0),
                     interpret=interpret)
    u = zz.astype(jnp.uint32)
    deltas = (u >> jnp.uint32(1)).astype(jnp.int32) ^ \
        -(u & jnp.uint32(1)).astype(jnp.int32)
    d_full = jnp.zeros(pid.shape[0], jnp.int32).at[dpos].set(
        jnp.where(jnp.arange(deltas.shape[0]) < n[0], deltas, 0))
    c = jnp.cumsum(d_full)
    return c - c[starts][pid] + firsts[pid]
