"""Public jit'd wrappers: on-device decode of TPQ-encoded column buffers.

``decode_on_device`` is the bridge between the storage layer
(:mod:`repro.core.encodings`) and the TPU: the host hands over the *encoded*
payload (as uint8/uint32 arrays) and the matching footer metadata; decode runs
as Pallas kernels next to the consumer.  This is the beyond-paper
serialization-bottleneck fix for TPU (DESIGN.md §2, §7).

``interpret`` defaults to True off-TPU so the whole path validates on CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import encodings as enc
from .bitunpack import bitunpack
from .bss_decode import bss_decode
from .delta_decode import delta_decode
from .dict_decode import dict_decode
from .filter_kernel import filter_range
from .segmented import (plan_segments, seg_bitunpack, seg_delta_decode,
                        seg_dict_decode)
from .stats_kernel import page_minmax

__all__ = ["bitunpack", "bss_decode", "delta_decode", "dict_decode",
           "filter_range", "page_minmax", "decode_on_device",
           "decode_batch_on_device", "default_interpret",
           "plan_segments", "seg_bitunpack", "seg_dict_decode",
           "seg_delta_decode"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _payload_words(payload: bytes) -> jnp.ndarray:
    pad = (-len(payload)) % 4
    if pad:
        payload = payload + b"\x00" * pad
    return jnp.asarray(np.frombuffer(payload, np.uint32))


def decode_on_device(encoding: str, meta: dict, payload: bytes, n: int,
                     np_dtype, *, interpret: bool = True) -> jnp.ndarray:
    """Device-side equivalent of ``encodings.decode`` for the kernelized
    encodings (BITPACK / DICT / DELTA / BSS).  Others fall back to host decode
    + transfer (PLAIN has nothing to decode anyway)."""
    dt = np.dtype(np_dtype)
    if encoding == enc.BITPACK:
        vals = bitunpack(_payload_words(payload), n, meta["bits"],
                         interpret=interpret)
        if dt == np.bool_:
            return vals.astype(jnp.bool_)
        return (vals + jnp.int32(meta["ref"])).astype(dt) \
            if meta["ref"] else vals.astype(dt)
    if encoding == enc.DICT:
        dl = meta["dict_len"]
        dictionary = jnp.asarray(
            np.frombuffer(payload[:dl], np.dtype(dt).newbyteorder("<")).astype(dt))
        idx = bitunpack(_payload_words(payload[dl:]), n, meta["bits"],
                        interpret=interpret)
        return dict_decode(idx, dictionary, interpret=interpret)
    if encoding == enc.DELTA:
        # encoder stores n-1 deltas; prepend a zero slot for the kernel
        zz = enc.unpack_bits(payload, n - 1, meta["bits"]) if n > 1 else \
            np.zeros(0, np.uint64)
        zz = jnp.asarray(np.concatenate([[0], zz]).astype(np.uint32))
        return delta_decode(zz, jnp.int32(meta["first"]),
                            interpret=interpret).astype(dt)
    if encoding == enc.BSS and dt == np.float32:
        planes = jnp.asarray(
            np.frombuffer(payload, np.uint8).reshape(dt.itemsize, n))
        return bss_decode(planes, interpret=interpret)
    # fallback: host decode, then transfer
    return jnp.asarray(enc.decode(encoding, meta, payload, n, dt))


def decode_batch_on_device(encoding: str, specs, np_dtype, *,
                           interpret: bool = True) -> np.ndarray:
    """ONE fused device dispatch decoding a whole morsel's pages of a single
    encoding group.

    ``specs`` is ``[(encoding, meta, payload, n), ...]`` with at least two
    non-empty pages, all the given ``encoding``; the caller
    (:meth:`JaxDecodeBackend.decode_batch`) has already proven every page
    32-bit exact.  Returns the concatenated value stream as a host array of
    ``np_dtype`` — byte-identical to per-page decode by construction.
    """
    dt = np.dtype(np_dtype)
    ns = np.array([n for _, _, _, n in specs], np.int64)
    ks = np.array([m["bits"] for _, m, _, _ in specs], np.int64)
    total = int(ns.sum())
    if encoding == enc.BITPACK:
        words, w0, sh, mask = plan_segments([p for _, _, p, _ in specs],
                                            ns, ks)
        refs = np.zeros(w0.shape[0], np.int32)
        if dt != np.bool_:
            refs[:total] = np.repeat(
                np.array([m["ref"] for _, m, _, _ in specs], np.int64), ns)
        vals = np.asarray(seg_bitunpack(words, w0, sh, mask, refs,
                                        interpret=interpret))
        return vals[:total].astype(dt, copy=False)
    if encoding == enc.DICT:
        le = dt.newbyteorder("<")
        dicts = [np.frombuffer(p[:m["dict_len"]], le)
                 for _, m, p, _ in specs]
        words, w0, sh, mask = plan_segments(
            [memoryview(p)[m["dict_len"]:] for _, m, p, _ in specs], ns, ks)
        off = np.zeros(len(dicts), np.int64)
        np.cumsum([len(d) for d in dicts[:-1]], out=off[1:])
        doff = np.zeros(w0.shape[0], np.int32)
        doff[:total] = np.repeat(off, ns)
        # the gather runs in 32-bit device lanes: the caller's gate proved
        # the dictionary VALUES fit, so the host-side narrow is lossless
        dcat = np.concatenate(dicts).astype(
            np.int32 if dt.kind in "iu" else dt)
        vals = np.asarray(seg_dict_decode(words, w0, sh, mask, dcat, doff,
                                          interpret=interpret))
        return vals[:total].astype(dt, copy=False)
    if encoding == enc.DELTA:
        # each page packs n-1 zigzag'd deltas; page-start slots are zero in
        # the scatter so one global cumsum recovers every page (wrap-exact)
        words, w0, sh, mask = plan_segments([p for _, _, p, _ in specs],
                                            ns - 1, ks)
        d_total = int((ns - 1).sum())
        starts = np.zeros(len(ns), np.int64)
        np.cumsum(ns[:-1], out=starts[1:])
        pad_out = 1 << max(total - 1, 0).bit_length()
        pid = np.zeros(pad_out, np.int32)
        pid[:total] = np.repeat(np.arange(len(ns), dtype=np.int32), ns)
        dmask = np.ones(total, bool)
        dmask[starts] = False
        # pad slots of dpos point at output slot 0 — a page start, whose
        # value is forced to zero anyway, so the padded scatter is harmless
        dpos = np.zeros(w0.shape[0], np.int32)
        dpos[:d_total] = np.nonzero(dmask)[0]
        firsts = np.array([m["first"] for _, m, _, _ in specs], np.int32)
        vals = np.asarray(seg_delta_decode(
            words, w0, sh, mask, dpos, starts.astype(np.int32), pid, firsts,
            np.array([d_total], np.int32), interpret=interpret))
        return vals[:total].astype(dt, copy=False)
    raise ValueError(f"no segmented kernel for encoding {encoding!r}")


def decode_and_filter(encoding: str, meta: dict, payload: bytes, n: int,
                      np_dtype, lo, hi, *, interpret: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused decode -> range predicate; returns (values, mask, block_counts)."""
    vals = decode_on_device(encoding, meta, payload, n, np_dtype,
                            interpret=interpret)
    mask, counts = filter_range(vals, lo, hi, interpret=interpret)
    return vals, mask, counts
