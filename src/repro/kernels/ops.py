"""Public jit'd wrappers: on-device decode of TPQ-encoded column buffers.

``decode_on_device`` is the bridge between the storage layer
(:mod:`repro.core.encodings`) and the TPU: the host hands over the *encoded*
payload (as uint8/uint32 arrays) and the matching footer metadata; decode runs
as Pallas kernels next to the consumer.  This is the beyond-paper
serialization-bottleneck fix for TPU (DESIGN.md §2, §7).

``interpret`` defaults to True off-TPU so the whole path validates on CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import encodings as enc
from .bitunpack import bitunpack
from .bss_decode import bss_decode
from .delta_decode import delta_decode
from .dict_decode import dict_decode
from .filter_kernel import filter_range
from .stats_kernel import page_minmax

__all__ = ["bitunpack", "bss_decode", "delta_decode", "dict_decode",
           "filter_range", "page_minmax", "decode_on_device",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _payload_words(payload: bytes) -> jnp.ndarray:
    pad = (-len(payload)) % 4
    if pad:
        payload = payload + b"\x00" * pad
    return jnp.asarray(np.frombuffer(payload, np.uint32))


def decode_on_device(encoding: str, meta: dict, payload: bytes, n: int,
                     np_dtype, *, interpret: bool = True) -> jnp.ndarray:
    """Device-side equivalent of ``encodings.decode`` for the kernelized
    encodings (BITPACK / DICT / DELTA / BSS).  Others fall back to host decode
    + transfer (PLAIN has nothing to decode anyway)."""
    dt = np.dtype(np_dtype)
    if encoding == enc.BITPACK:
        vals = bitunpack(_payload_words(payload), n, meta["bits"],
                         interpret=interpret)
        if dt == np.bool_:
            return vals.astype(jnp.bool_)
        return (vals + jnp.int32(meta["ref"])).astype(dt) \
            if meta["ref"] else vals.astype(dt)
    if encoding == enc.DICT:
        dl = meta["dict_len"]
        dictionary = jnp.asarray(
            np.frombuffer(payload[:dl], np.dtype(dt).newbyteorder("<")).astype(dt))
        idx = bitunpack(_payload_words(payload[dl:]), n, meta["bits"],
                        interpret=interpret)
        return dict_decode(idx, dictionary, interpret=interpret)
    if encoding == enc.DELTA:
        # encoder stores n-1 deltas; prepend a zero slot for the kernel
        zz = enc.unpack_bits(payload, n - 1, meta["bits"]) if n > 1 else \
            np.zeros(0, np.uint64)
        zz = jnp.asarray(np.concatenate([[0], zz]).astype(np.uint32))
        return delta_decode(zz, jnp.int32(meta["first"]),
                            interpret=interpret).astype(dt)
    if encoding == enc.BSS and dt == np.float32:
        planes = jnp.asarray(
            np.frombuffer(payload, np.uint8).reshape(dt.itemsize, n))
        return bss_decode(planes, interpret=interpret)
    # fallback: host decode, then transfer
    return jnp.asarray(enc.decode(encoding, meta, payload, n, dt))


def decode_and_filter(encoding: str, meta: dict, payload: bytes, n: int,
                      np_dtype, lo, hi, *, interpret: bool = True
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused decode -> range predicate; returns (values, mask, block_counts)."""
    vals = decode_on_device(encoding, meta, payload, n, np_dtype,
                            interpret=interpret)
    mask, counts = filter_range(vals, lo, hi, interpret=interpret)
    return vals, mask, counts
