"""Pure-jnp oracles for every Pallas kernel in this package.

These mirror the numpy codecs in :mod:`repro.core.encodings` but are written
in jnp so the kernels can be validated shape-for-shape on any backend.
"""
from __future__ import annotations

import jax.lax
import jax.numpy as jnp


def bitunpack(words: jnp.ndarray, n: int, k: int) -> jnp.ndarray:
    """Unpack n k-bit values from a little-endian uint32 word stream."""
    if k == 0:
        return jnp.zeros(n, jnp.int32)
    j = jnp.arange(n, dtype=jnp.uint32)
    bit = j * jnp.uint32(k)
    w0 = (bit >> 5).astype(jnp.int32)
    shift = bit & jnp.uint32(31)
    words = words.astype(jnp.uint32)
    lo = words[w0] >> shift
    # high part (guard shift-by-32: select, don't rely on UB)
    w1 = jnp.minimum(w0 + 1, words.shape[0] - 1)
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   words[w1] << (jnp.uint32(32) - shift))
    mask = jnp.uint32((1 << k) - 1) if k < 32 else jnp.uint32(0xFFFFFFFF)
    return ((lo | hi) & mask).astype(jnp.int32)


def dict_decode(indices: jnp.ndarray, dictionary: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(dictionary, indices.astype(jnp.int32), axis=0)


def unzigzag32(u: jnp.ndarray) -> jnp.ndarray:
    u = u.astype(jnp.uint32)
    return ((u >> jnp.uint32(1)) ^ (-(u & jnp.uint32(1)).astype(jnp.int32)).astype(jnp.uint32)).astype(jnp.int32)


def delta_decode(zz: jnp.ndarray, first: jnp.ndarray) -> jnp.ndarray:
    """zz: zigzag'd deltas with a leading 0 slot; out[i] = first + cumsum."""
    deltas = unzigzag32(zz)
    return (first.astype(jnp.int32) + jnp.cumsum(deltas, dtype=jnp.int32))


def bss_decode(byte_planes: jnp.ndarray) -> jnp.ndarray:
    """byte_planes: (4, n) uint8 split-stream -> float32 (n,)."""
    b = byte_planes.astype(jnp.uint32)
    word = b[0] | (b[1] << 8) | (b[2] << 16) | (b[3] << 24)
    return jax.lax.bitcast_convert_type(word, jnp.float32)


def filter_range(x: jnp.ndarray, lo, hi) -> jnp.ndarray:
    return (x >= lo) & (x <= hi)


def page_minmax(x: jnp.ndarray, page: int):
    """Per-page (min, max) for n divisible by page."""
    r = x.reshape(-1, page)
    return r.min(axis=1), r.max(axis=1)
