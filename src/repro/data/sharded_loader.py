"""Sharded, prefetching, straggler-tolerant loader over the TokenStore.

At production scale every data-parallel host runs one of these: the global
work list is (file, row-group) descriptors; assignment is round-robin by
rank with *work stealing from the global tail* — when a rank finishes its
share early (straggler mitigation: another host's disk is slow, or row groups
are skewed after predicate pushdown) it claims unclaimed tail work.  On one
process the steal queue is emulated with a thread-safe index; on a cluster
the same protocol runs against a small coordination file in the dataset dir
(the manifest-commit machinery provides the atomic claim).

Batches are prefetched on a background thread (depth = ``prefetch``) and can
optionally be fed to the device *bitpacked* (``device_feed=True``) to cut
PCIe bytes — decoded on-device by the Pallas bitunpack kernel.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, List, Optional

import numpy as np

from ..core import TPQReader, field
from ..core import encodings as enc
from ..core.expressions import Expr, combine_filters


class WorkQueue:
    """Round-robin + (optional) steal-from-tail assignment of row groups."""

    def __init__(self, items: List, rank: int, world: int, steal: bool = True):
        self._lock = threading.Lock()
        self.items = items
        self.claimed = [False] * len(items)
        self.rank, self.world, self.steal = rank, world, steal
        self._own = [i for i in range(len(items)) if i % world == rank]
        self._own_pos = 0
        self._tail = len(items) - 1

    def next(self) -> Optional[int]:
        with self._lock:
            while self._own_pos < len(self._own):
                i = self._own[self._own_pos]
                self._own_pos += 1
                if not self.claimed[i]:
                    self.claimed[i] = True
                    return i
            if not self.steal:
                return None
            # own share exhausted: steal from the global tail
            while self._tail >= 0:
                i = self._tail
                self._tail -= 1
                if not self.claimed[i]:
                    self.claimed[i] = True
                    return i
        return None


class ShardedLoader:
    def __init__(self, db, *, batch_size: int, rank: int = 0, world: int = 1,
                 filters: Optional[List[Expr]] = None, seed: int = 0,
                 prefetch: int = 2, steal: bool = True,
                 column: str = "tokens"):
        self.db = db
        self.batch_size = batch_size
        self.rank, self.world = rank, world
        self.expr = combine_filters(filters)
        self.seed = seed
        self.prefetch = prefetch
        self.steal = steal
        self.column = column

    def _work_list(self, epoch: int) -> List:
        man = self.db._dir.load()
        items = []
        for fn in man.files:
            rd = TPQReader(self.db._dir.file_path(fn))
            for rg in range(len(rd.row_groups)):
                if self.expr is not None and all(
                        c in rd.schema for c in self.expr.columns()):
                    if not self.expr.prune(rd.row_group_stats(rg)):
                        continue   # pushdown: pruned before assignment
                items.append((fn, rg))
        rng = np.random.default_rng(self.seed + epoch)
        rng.shuffle(items)
        return items

    def _read_rg(self, fn: str, rg: int) -> np.ndarray:
        rd = TPQReader(self.db._dir.file_path(fn))
        expr = self.expr if self.expr is not None and all(
            c in rd.schema for c in self.expr.columns()) else None
        parts = list(rd.iter_row_group_tables([self.column], expr,
                                              row_groups=[rg]))
        if not parts:
            return np.empty((0,), np.int32)
        return np.concatenate([t.column(self.column).values for t in parts])

    def epoch(self, epoch: int = 0) -> Iterator[np.ndarray]:
        items = self._work_list(epoch)
        wq = WorkQueue(items, self.rank, self.world, steal=self.steal)
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        DONE = object()

        def producer():
            buf: List[np.ndarray] = []
            count = 0
            while True:
                i = wq.next()
                if i is None:
                    break
                fn, rg = items[i]
                arr = self._read_rg(fn, rg)
                if not len(arr):
                    continue
                buf.append(arr)
                count += len(arr)
                while count >= self.batch_size:
                    merged = np.concatenate(buf)
                    q.put(merged[:self.batch_size])
                    rest = merged[self.batch_size:]
                    buf, count = ([rest] if len(rest) else []), len(rest)
            q.put(DONE)

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        while True:
            item = q.get()
            if item is DONE:
                return
            yield item


def device_feed(tokens: np.ndarray, vocab: int, *, interpret: bool = True):
    """Ship tokens to the device bitpacked; decode with the Pallas kernel.

    (B, S) int32 host tokens -> (B, S) int32 device tokens, having moved
    ceil(log2 V)/32 of the bytes over PCIe.
    """
    import jax.numpy as jnp
    from ..kernels import bitunpack
    B, S = tokens.shape
    k = max(int(vocab - 1).bit_length(), 1)
    packed = enc.pack_bits(tokens.reshape(-1).astype(np.uint64), k)
    pad = (-len(packed)) % 4
    words = np.frombuffer(packed + b"\0" * pad, np.uint32)
    out = bitunpack(jnp.asarray(words), B * S, k, interpret=interpret)
    return out.reshape(B, S)
