"""TokenStore: pretokenized training data in the paper's columnar store.

Each row is one fixed-length sequence: tokens (tensor<i4, (S,)>), plus
filterable metadata columns (domain, quality, n_tokens, doc_id).  The paper's
two pushdowns become data-pipeline features:

* projection pushdown — training reads ONLY the ``tokens`` column; metadata
  bytes never leave disk;
* predicate pushdown — quality/domain filters prune whole row groups from the
  footer statistics before any token is read.

Tokens are written with BITPACK field encoding (ceil(log2 V) bits/token, e.g.
18 for a 152k vocab vs 32 for int32) — the host can also ship the *packed*
stream to the device and decode with the Pallas bitunpack kernel
(``device_feed``), which is the beyond-paper PCIe-bandwidth optimization.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from ..core import ParquetDB, Table, field
from ..core import encodings as enc
from ..core.store import LoadConfig


class TokenStore:
    def __init__(self, path: str, seq_len: int, vocab: int,
                 codec: str = "zlib"):
        self.seq_len = seq_len
        self.vocab = vocab
        self.db = ParquetDB(
            path, "tokens", codec=codec,
            field_encodings={"tokens": enc.BITPACK},
            with_bloom=False)

    # -- write -------------------------------------------------------------------
    def append_documents(self, token_arrays: Sequence[np.ndarray],
                         domain: str = "default",
                         quality: Optional[Sequence[float]] = None) -> int:
        """Pack documents into fixed-length rows and append."""
        flat = np.concatenate([np.asarray(t, np.int32) for t in token_arrays])
        n_seq = len(flat) // self.seq_len
        if n_seq == 0:
            return 0
        seqs = flat[:n_seq * self.seq_len].reshape(n_seq, self.seq_len)
        q = (np.asarray(quality, np.float32)[:n_seq] if quality is not None
             else np.ones(n_seq, np.float32))
        self.db.create({
            "tokens": seqs,
            "domain": [domain] * n_seq,
            "quality": q,
            "n_tokens": np.full(n_seq, self.seq_len, np.int32),
        })
        return n_seq

    @property
    def n_sequences(self) -> int:
        return self.db.n_rows

    # -- read --------------------------------------------------------------------
    def read_batches(self, batch_size: int, *, dp_rank: int = 0,
                     dp_size: int = 1, seed: int = 0, epoch: int = 0,
                     min_quality: Optional[float] = None,
                     domains: Optional[List[str]] = None,
                     drop_remainder: bool = True) -> Iterator[np.ndarray]:
        """Yield (batch_size, seq_len) int32 arrays for this data-parallel rank.

        Work distribution is at row-group granularity: the global shuffled
        row-group list is dealt round-robin to ranks; a rank that exhausts its
        share steals from the global tail (straggler mitigation — see
        ``sharded_loader``).
        """
        filters = []
        if min_quality is not None:
            filters.append(field("quality") >= float(min_quality))
        if domains is not None:
            filters.append(field("domain").isin(domains))
        gen = self.db.read(columns=["tokens"], filters=filters or None,
                           load_format="batches", batch_size=batch_size * 4,
                           load_config=LoadConfig(use_threads=False))
        buf: List[np.ndarray] = []
        count = 0
        rng = np.random.default_rng(seed + epoch)
        idx = 0
        for t in gen:
            arr = t.column("tokens").values
            take = arr
            if dp_size > 1:
                # deal rows round-robin to ranks (deterministic)
                take = arr[dp_rank::dp_size]
            perm = rng.permutation(len(take))
            take = take[perm]
            buf.append(take)
            count += len(take)
            idx += 1
            while count >= batch_size:
                merged = np.concatenate(buf)
                yield merged[:batch_size]
                rest = merged[batch_size:]
                buf, count = ([rest] if len(rest) else []), len(rest)
        if buf and not drop_remainder:
            yield np.concatenate(buf)
