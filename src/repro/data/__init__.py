"""Data pipeline on the columnar store: token storage + sharded loading."""
