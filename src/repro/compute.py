"""repro.compute — analogue of the ``pyarrow.compute`` surface the paper uses.

The paper's examples read as ``import pyarrow.compute as pc``; this module
provides the same names (``pc.field``, ``pc.min_max``, ``pc.if_else``,
``pc.list_flatten``, ``pc.list_parent_indices``, ``pc.equal``, ``pc.filter``,
``pc.take``) against repro.core tables/columns so the paper's §6 workload runs
verbatim modulo the import line.
"""
from __future__ import annotations

from typing import Any, Union

import numpy as np

from .core.expressions import Expr, field  # re-export: pc.field
from .core.table import Column, Table
from .core.dtypes import KIND_LIST, KIND_NUMERIC

__all__ = ["field", "min_max", "if_else", "list_flatten",
           "list_parent_indices", "equal", "filter", "take", "sum", "mean",
           "unique"]


def if_else(cond: Expr, then_expr: Expr, else_expr: Expr) -> Expr:
    """Conditional *predicate*: rows satisfy then_expr where cond holds,
    else_expr elsewhere — exactly the paper's band-gap query pattern."""
    return (cond & then_expr) | (~cond & else_expr)


def _as_values(col: Union[Column, np.ndarray]) -> np.ndarray:
    if isinstance(col, Column):
        if col.dtype.kind != KIND_NUMERIC:
            raise TypeError(f"numeric column required, got {col.dtype}")
        if col.validity is not None:
            return col.values[col.validity]
        return col.values
    return np.asarray(col)


def min_max(col: Union[Column, np.ndarray]) -> dict:
    v = _as_values(col)
    return {"min": v.min().item() if len(v) else None,
            "max": v.max().item() if len(v) else None}


def sum(col: Union[Column, np.ndarray]):  # noqa: A001 - mirrors pc.sum
    return _as_values(col).sum().item()


def mean(col: Union[Column, np.ndarray]):
    return _as_values(col).mean().item()


def unique(col: Union[Column, np.ndarray]) -> np.ndarray:
    return np.unique(_as_values(col))


def list_flatten(col: Column) -> Column:
    if col.dtype.kind != KIND_LIST:
        raise TypeError(f"list column required, got {col.dtype}")
    return col.child


def list_parent_indices(col: Column) -> np.ndarray:
    if col.dtype.kind != KIND_LIST:
        raise TypeError(f"list column required, got {col.dtype}")
    lens = np.diff(col.offsets)
    return np.repeat(np.arange(len(col), dtype=np.int64), lens)


def equal(a, b) -> np.ndarray:
    av = a.to_pylist() if isinstance(a, Column) and a.dtype.kind not in (KIND_NUMERIC,) else a
    if isinstance(av, Column):
        av = av.values
    if isinstance(av, list):
        av = np.array(av, dtype=object)
    return np.asarray(av == b) if not isinstance(b, Column) else np.asarray(av == b.values)


def filter(obj: Union[Table, Column, np.ndarray], mask: np.ndarray):  # noqa: A001
    mask = np.asarray(mask, bool)
    if isinstance(obj, Table):
        return obj.filter_mask(mask)
    if isinstance(obj, Column):
        return obj.take(np.nonzero(mask)[0])
    return obj[mask]


def take(obj: Union[Table, Column, np.ndarray], indices) -> Any:
    idx = np.asarray(indices, np.int64)
    if isinstance(obj, (Table, Column)):
        return obj.take(idx)
    return obj[idx]
