"""Loop-aware cost analysis over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts a while-loop body
ONCE — a 64-layer ``lax.scan`` is undercounted 64×, for flops, bytes AND the
collectives inside the loop (verified by calibration: a lax.scan of 10
matmuls reports 1 matmul).  This module re-derives the three roofline inputs
from the compiled HLO text with loop multipliers:

  * flops        — 2·prod(out)·prod(contracted) per dot, ×∏(enclosing trip
                   counts); fusion-internal dots included;
  * memory bytes — per-instruction operand+output bytes at fusion granularity
                   (fusion internals don't touch HBM), ×trip counts;
  * collective bytes — operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute,
                   ×trip counts, split per op kind.

Trip counts come from the ``known_trip_count`` backend_config XLA attaches to
rolled loops.  Shapes are per-device in post-SPMD HLO, so every number is
per-device.  Elementwise flops are ignored (dots dominate every cell here);
the roofline notes call this out.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|branch_computations=\{[^}]*)=?%?([\w\.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "copy", "copy-start", "copy-done", "after-all",
                   "iota", "while", "conditional", "call"}


def _shape_list_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    out_bytes: int
    operands: List[str]
    flops: float = 0.0
    trip: int = 1
    called: List[str] = dataclasses.field(default_factory=list)
    fusion_called: List[str] = dataclasses.field(default_factory=list)
    collective: Optional[str] = None


class HLOModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.shapes: Dict[Tuple[str, str], str] = {}
        self.fusion_targets: Set[str] = set()
        self.entry: Optional[str] = None
        self._parse(text)
        self._compute_dot_flops()

    # -- parsing ---------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            # computation definitions start at column 0 and end with '{';
            # instruction lines are indented.  (Signatures may contain
            # '/*index=N*/' comments, so don't key off '='.)
            if line and not raw.startswith(" ") and line.endswith("{") \
                    and "->" in line:
                mname = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*[\s(]", line)
                if mname:
                    cur = mname.group(1)
                    self.computations[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, result_type, opcode = mi.groups()
            # operand region: inside the first balanced paren group after opcode
            paren = line.find(opcode + "(") + len(opcode)
            depth, j = 0, paren
            for j in range(paren, len(line)):
                if line[j] == "(":
                    depth += 1
                elif line[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operand_str = line[paren:j + 1]
            attrs = line[j + 1:]
            operands = _OPERAND_RE.findall(operand_str)
            ins = Instr(name=name, opcode=opcode, result_type=result_type,
                        out_bytes=_shape_list_bytes(result_type),
                        operands=operands)
            if opcode == "while":
                mt = _TRIP_RE.search(attrs)
                ins.trip = int(mt.group(1)) if mt else 1
                mb, mcnd = _BODY_RE.search(attrs), _COND_RE.search(attrs)
                ins.called = [m.group(1) for m in (mb, mcnd) if m]
            elif opcode == "fusion":
                mcall = _CALLS_RE.search(attrs)
                if mcall:
                    ins.fusion_called = [mcall.group(1)]
                    self.fusion_targets.add(mcall.group(1))
            elif opcode in ("call", "async-start", "custom-call"):
                mcall = _CALLS_RE.search(attrs)
                if mcall:
                    ins.called = [mcall.group(1)]
            elif opcode == "conditional":
                ins.called = _BRANCH_RE.findall(attrs)
            base = opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not opcode.endswith("-done"):
                ins.collective = base
            if opcode in ("dot", "convolution"):
                mlhs = _LHS_C_RE.search(attrs)
                ins.called = []
                ins._lhs_contract = ([int(x) for x in mlhs.group(1).split(",")
                                      if x] if mlhs else [])
            self.computations[cur].append(ins)
            self.shapes[(cur, name)] = result_type

    def _compute_dot_flops(self) -> None:
        for comp, instrs in self.computations.items():
            for ins in instrs:
                if ins.opcode not in ("dot", "convolution"):
                    continue
                out_dims = _first_shape_dims(ins.result_type) or []
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                contract = 1
                lhs = ins.operands[0] if ins.operands else None
                lhs_type = self.shapes.get((comp, lhs), "") if lhs else ""
                lhs_dims = _first_shape_dims(lhs_type) or []
                for i in getattr(ins, "_lhs_contract", []):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
                ins.flops = 2.0 * out_elems * max(contract, 1)

    # -- walking ------------------------------------------------------------------
    def analyze(self, top_n: int = 0) -> Dict:
        flops = 0.0
        mem_bytes = 0.0
        coll: Dict[str, float] = {}
        coll_count: Dict[str, int] = {}
        contributors: List[Tuple[float, str, str, str]] = []

        def op_bytes(comp: str, ins: Instr) -> float:
            if ins.opcode in _SKIP_BYTES_OPS:
                return 0.0
            # aliasing/windowed ops: traffic is the window, not the buffer.
            # (a scan's residual stack is updated in place every iteration —
            # counting the whole buffer per step overestimates 100x)
            if ins.opcode == "dynamic-update-slice":
                upd = (self.shapes.get((comp, ins.operands[1]), "")
                       if len(ins.operands) > 1 else "")
                return 2.0 * _shape_list_bytes(upd)
            if ins.opcode in ("dynamic-slice", "gather", "slice"):
                return 2.0 * float(ins.out_bytes)
            if ins.opcode == "scatter":
                upd = (self.shapes.get((comp, ins.operands[-1]), "")
                       if ins.operands else "")
                return 2.0 * _shape_list_bytes(upd)
            total = float(ins.out_bytes)
            skip_alias = None
            if ins.opcode == "fusion" and ins.fusion_called:
                # loop fusion around an in-place dynamic-update-slice: the
                # full-buffer operand is aliased with the output — its bytes
                # are not traffic; count the update window via out_bytes only
                inner = self.computations.get(ins.fusion_called[0], [])
                if any(x.opcode == "dynamic-update-slice" for x in inner):
                    for o in ins.operands:
                        t = self.shapes.get((comp, o), "")
                        if t and _shape_list_bytes(t) == ins.out_bytes:
                            skip_alias = o
                            total = 0.0  # output aliased too
                            break
            for o in ins.operands:
                if o == skip_alias:
                    continue
                t = self.shapes.get((comp, o))
                if t:
                    total += _shape_list_bytes(t)
            return total

        def walk(comp: str, mult: float, in_fusion: bool, depth: int = 0):
            nonlocal flops, mem_bytes
            if depth > 50 or comp not in self.computations:
                return
            for ins in self.computations[comp]:
                flops += ins.flops * mult
                if not in_fusion:
                    b = op_bytes(comp, ins) * mult
                    mem_bytes += b
                    if top_n and b > 0:
                        contributors.append(
                            (b, ins.opcode, ins.result_type[:70],
                             f"x{mult:.0f}"))
                    if ins.collective:
                        cb = sum(_shape_list_bytes(self.shapes.get((comp, o), ""))
                                 for o in ins.operands)
                        if cb == 0:
                            cb = ins.out_bytes
                        coll[ins.collective] = coll.get(ins.collective, 0) + cb * mult
                        coll_count[ins.collective] = \
                            coll_count.get(ins.collective, 0) + 1
                for f in ins.fusion_called:
                    walk(f, mult, True, depth + 1)
                for c in ins.called:
                    walk(c, mult * ins.trip, in_fusion, depth + 1)

        if self.entry:
            walk(self.entry, 1.0, False)
        out = {
            "flops": flops,
            "memory_bytes": mem_bytes,
            "collective_bytes": {**coll, "total_bytes": sum(coll.values()),
                                 "counts": coll_count},
        }
        if top_n:
            contributors.sort(reverse=True)
            out["top_bytes"] = contributors[:top_n]
        return out


def analyze_hlo(text: str) -> Dict:
    return HLOModule(text).analyze()
