import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating any real tensors:
  * compiled.memory_analysis()  — per-device footprint (proves it fits),
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the post-SPMD HLO text,
and appends a JSON record under benchmarks/dryrun_results/ that
``launch/roofline.py`` aggregates into EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax
import numpy as np

from ..configs import registry
from ..models import Model
from ..train import optimizer as opt
from ..train.train_step import build_serve_step, build_train_step
from .mesh import make_production_mesh

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-device collective operand bytes, summed per op kind.

    Operates on post-SPMD HLO: shapes are per-device.  For each collective
    instruction line, the first shape is the result; subsequent shapes are
    operands — we sum operand bytes (the §Roofline recipe).
    """
    out: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        rhs = line.split("= ", 1)[1]
        shapes_rhs = _SHAPE_RE.findall(rhs)
        # result shape(s) come before the op name; operands after the '('
        paren = rhs.find("(")
        operand_shapes = _SHAPE_RE.findall(rhs[paren:]) if paren >= 0 else []
        if not operand_shapes:
            operand_shapes = shapes_rhs[1:] or shapes_rhs
        b = sum(_shape_bytes(d, s) for d, s in operand_shapes)
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    out["total_bytes"] = sum(v for k, v in out.items())
    out["counts"] = count
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               microbatches: int = 1, verbose: bool = True,
               overrides: Optional[Dict] = None,
               grad_dtype: str = "float32") -> Dict:
    import dataclasses as _dc
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = registry.get(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    model = Model(cfg)
    shape = registry.SHAPES[shape_name]
    t0 = time.time()

    if shape.kind == "train":
        _, jit_step, shards = build_train_step(
            model, mesh, opt.OptConfig(grad_dtype=grad_dtype),
            microbatches=microbatches)
        specs = model.input_specs(shape.global_batch, shape.seq_len)
        abs_params = model.init_abstract()
        abs_opt = jax.eval_shape(opt.init_opt_state, abs_params)
        lowered = jit_step(specs).lower(abs_params, abs_opt, specs)
    elif shape.kind == "prefill":
        jit_serve, jit_prefill, _ = build_serve_step(model, mesh)
        specs = model.input_specs(shape.global_batch, shape.seq_len)
        abs_params = _bf16(model.init_abstract())
        fn = jit_prefill(specs, cache_len=shape.seq_len)
        lowered = fn.lower(abs_params, specs)
    else:  # decode: one new token against a seq_len-deep cache
        jit_serve, _, _ = build_serve_step(model, mesh)
        fn, c_shard = jit_serve(shape.global_batch, shape.seq_len)
        abs_params = _bf16(model.init_abstract())
        cache_abs = model.cache_abstract(shape.global_batch, shape.seq_len)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jax.numpy.int32)
        pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
        lowered = fn.lower(abs_params, cache_abs, tok, pos)
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    cost = dict(compiled.cost_analysis())
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # loop-aware analysis (XLA's aggregate counts scan bodies once — see
    # hlo_cost.py calibration); these are the roofline inputs.
    from .hlo_cost import analyze_hlo
    la = analyze_hlo(hlo)
    coll = la["collective_bytes"]
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "flops_per_device": la["flops"],
        "bytes_per_device": la["memory_bytes"],
        "collectives": coll,
        "xla_raw": {"flops": cost.get("flops", 0.0),
                    "bytes": cost.get("bytes accessed", 0.0),
                    "collective_bytes":
                        collective_stats(hlo).get("total_bytes", 0.0)},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "model": {
            "total_params": cfg.total_params_estimate(),
            "active_params": cfg.active_params_estimate(),
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} × {shape_name}: "
              f"compile {t_compile:.1f}s  "
              f"flops/dev {rec['flops_per_device']:.3e}  "
              f"bytes/dev {rec['bytes_per_device']:.3e}  "
              f"coll/dev {coll.get('total_bytes', 0):.3e}B")
        print("  memory_analysis:", rec["memory"])
    return rec


def _bf16(tree):
    import jax.numpy as jnp
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, tree)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=list(registry.SHAPES))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose result JSON already exists")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override, e.g. --set attn_scores_bf16=1")
    ap.add_argument("--grad-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    args = ap.parse_args(argv)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = (v in ("1", "true", "True")) if v in (
            "0", "1", "true", "false", "True", "False") else (
            int(v) if v.isdigit() else v)

    cells = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in registry.ARCH_NAMES:
            for s in registry.cells_for(a):
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        tag = "2x16x16" if multi_pod else "16x16"
        for arch, shape in cells:
            fname = f"{tag}_{arch}_{shape}"
            if args.tag:
                fname += f"_{args.tag}"
            path = os.path.join(args.out, fname + ".json")
            if args.resume and os.path.exists(path):
                print(f"skip (exists): {tag} {arch} {shape}")
                continue
            try:
                rec = lower_cell(arch, shape, multi_pod=multi_pod,
                                 microbatches=args.microbatches,
                                 overrides=overrides,
                                 grad_dtype=args.grad_dtype)
                rec["tag"] = args.tag
                rec["overrides"] = {**overrides,
                                    "grad_dtype": args.grad_dtype,
                                    "microbatches": args.microbatches}
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
            except Exception as e:  # a failing cell is a bug — surface it
                failures.append((tag, arch, shape, repr(e)))
                print(f"FAIL {tag} {arch} {shape}: {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall requested cells lowered+compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
