"""Serving launcher: batched decode with the continuous-batching engine.

``python -m repro.launch.serve --arch qwen2.5-3b --reduced --requests 8``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import registry
from ..models import Model
from ..serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.submit(rng.integers(0, cfg.vocab, plen).astype(np.int32),
                   max_new_tokens=args.max_new)
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s aggregate)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:8]}...")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
