"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device state — the 512-placeholder-device
XLA_FLAGS dance happens only inside ``dryrun.py``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips."""
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(dryrun.py sets this automatically)")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use e.g. (2, 4) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


# v5e-class hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
