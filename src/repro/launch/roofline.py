"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, from the JSON records dryrun.py wrote:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective term = collective_bytes_per_device / link_bw       (~50 GB/s)

(cost_analysis numbers are already per-device on a post-SPMD module, so the
"/chips" in the spec formulas is baked in.)  Also reports MODEL_FLOPS = 6·N·D
(N = active params for MoE) and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs × chips) — remat/redundancy waste shows up here.

Usage: python -m repro.launch.roofline [--dir benchmarks/dryrun_results]
           [--format md|csv] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def tokens_of(rec: Dict) -> float:
    if rec["kind"] == "train":
        return rec["global_batch"] * rec["seq_len"]
    if rec["kind"] == "prefill":
        return rec["global_batch"] * rec["seq_len"]
    return rec["global_batch"] * 1.0   # decode: one token per sequence


def analyze(rec: Dict) -> Dict:
    flops = rec["flops_per_device"]
    bytes_ = rec["bytes_per_device"]
    coll = rec["collectives"].get("total_bytes", 0.0)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    n_active = rec["model"]["active_params"]
    d_tokens = tokens_of(rec)
    model_flops = 6.0 * n_active * d_tokens
    if rec["kind"] != "train":
        model_flops /= 3.0             # forward only: 2·N·D
    hlo_total = flops * rec["n_devices"]
    useful = model_flops / hlo_total if hlo_total else 0.0
    bound = terms[dominant]
    mfu_bound = (model_flops / rec["n_devices"] / PEAK_FLOPS_BF16) / bound \
        if bound else 0.0
    return {**rec, "t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dominant,
            "model_flops": model_flops, "useful_ratio": useful,
            "roofline_fraction": min(mfu_bound, 1.0)}


def load(dirpath: str, mesh: str = None) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as fh:
            rec = json.load(fh)
        if mesh and rec["mesh"] != mesh:
            continue
        recs.append(analyze(rec))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(recs: List[Dict], fmt: str = "md") -> str:
    hdr = ["mesh", "arch", "shape", "t_compute", "t_memory", "t_collective",
           "dominant", "useful", "roofline_frac"]
    rows = []
    for r in recs:
        rows.append([r["mesh"], r["arch"], r["shape"],
                     fmt_s(r["t_compute"]), fmt_s(r["t_memory"]),
                     fmt_s(r["t_collective"]), r["dominant"],
                     f"{r['useful_ratio']:.2f}",
                     f"{r['roofline_fraction']:.3f}"])
    if fmt == "csv":
        return "\n".join(",".join(h for h in hdr) + "\n" if i == 0 else
                         ",".join(row) for i, row in enumerate([hdr] + rows))
    w = [max(len(str(r[i])) for r in [hdr] + rows) for i in range(len(hdr))]
    lines = ["| " + " | ".join(str(h).ljust(w[i]) for i, h in enumerate(hdr)) + " |",
             "|" + "|".join("-" * (w[i] + 2) for i in range(len(hdr))) + "|"]
    for row in rows:
        lines.append("| " + " | ".join(str(c).ljust(w[i])
                                       for i, c in enumerate(row)) + " |")
    return "\n".join(lines)


def compare_table(base_dir: str, opt_dir: str, mesh: str = "16x16") -> str:
    """Baseline vs optimized: per-cell term ratios (baseline / optimized)."""
    base = {(r["arch"], r["shape"]): r for r in load(base_dir, mesh)}
    opt = {(r["arch"], r["shape"]): r for r in load(opt_dir, mesh)}
    lines = ["| arch × shape | mem base | mem opt | ×mem | ×flops | ×coll | dominant (opt) |",
             "|---|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]

        def ratio(a, c):
            return a / c if c else float("inf")
        lines.append(
            f"| {key[0]} × {key[1]} | {fmt_s(b['t_memory'])} | "
            f"{fmt_s(o['t_memory'])} | "
            f"{ratio(b['bytes_per_device'], o['bytes_per_device']):.1f} | "
            f"{ratio(b['flops_per_device'], o['flops_per_device']):.1f} | "
            f"{ratio(b['collectives'].get('total_bytes', 0), o['collectives'].get('total_bytes', 1)):.1f} | "
            f"{o['dominant']} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/dryrun_results")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--format", default="md", choices=["md", "csv"])
    ap.add_argument("--compare", default=None,
                    help="optimized-results dir: print baseline-vs-opt ratios")
    args = ap.parse_args(argv)
    if args.compare:
        print(compare_table(args.dir, args.compare, args.mesh or "16x16"))
        return 0
    recs = load(args.dir, args.mesh)
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first")
        return 1
    print(table(recs, args.format))
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
