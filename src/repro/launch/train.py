"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container you run the reduced configs (smoke scale); on a real
TPU slice the same entry point takes the full configs and the production
mesh.  Data comes from a columnar TokenStore (synthesized on the fly if the
path is empty), checkpoints/metrics go into columnar stores.
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from ..configs import registry
from ..data.sharded_loader import ShardedLoader
from ..data.tokenstore import TokenStore
from ..models import Model
from ..train.optimizer import OptConfig
from ..train.trainer import Trainer
from .mesh import make_mesh, make_production_mesh


def synthesize_corpus(ts: TokenStore, vocab: int, n_docs: int = 200,
                      seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, vocab, rng.integers(64, 2048))
            for _ in range(n_docs)]
    return ts.append_documents(docs, domain="synthetic")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default=None,
                    help="'production', 'multi-pod', or 'DxM' e.g. 2x4")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--data", default=None, help="TokenStore path")
    args = ap.parse_args(argv)

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get(args.arch)
    model = Model(cfg)
    if args.mesh in (None, "auto"):
        n = len(jax.devices())
        mesh = make_mesh((1, n) if n > 1 else (1, 1), ("data", "model"))
    elif args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "multi-pod":
        mesh = make_production_mesh(multi_pod=True)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    data_path = args.data or os.path.join(args.workdir, "tokens")
    ts = TokenStore(data_path, seq_len=args.seq, vocab=cfg.vocab)
    if ts.n_sequences < args.batch:
        n = synthesize_corpus(ts, cfg.vocab)
        print(f"synthesized {n} sequences into {data_path}")

    loader = ShardedLoader(ts.db, batch_size=args.batch)

    def batches():
        epoch = 0
        while True:
            got = False
            for b in loader.epoch(epoch):
                got = True
                batch = {"tokens": b}
                if cfg.frontend is not None or cfg.family == "encdec":
                    from ..models.frontends import synthetic_embeds
                    batch["embeds"] = synthetic_embeds(cfg, b.shape[0])
                yield batch
            epoch += 1
            if not got:
                raise RuntimeError("empty token store")

    trainer = Trainer(model, mesh,
                      OptConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps),
                      ckpt_dir=os.path.join(args.workdir, "ckpt"),
                      metrics_dir=os.path.join(args.workdir, "metrics"),
                      microbatches=args.microbatches)
    out = trainer.run(batches(), steps=args.steps)
    print(f"done: steps={out['steps']} final_loss={out['final_loss']:.4f} "
          f"(first={out['history'][0]:.4f})")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
