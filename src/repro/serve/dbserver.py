"""Concurrent query server over one ParquetDB dataset.

An asyncio TCP server speaking the length-prefixed JSON protocol of
:mod:`repro.serve.protocol`, exposing the full ``db.query()`` surface —
``where`` / ``select`` / ``group_by`` / ``order_by`` / ``limit`` /
aggregates — plus ``update`` / ``delete`` writes.  Three mechanisms make
it safe to point real traffic at:

**Admission control + backpressure.**  At most ``max_concurrent`` requests
execute at once; up to ``max_queue`` more wait.  Beyond that the server
*sheds*: an immediate ``503`` response with the current queue depth, never
an unbounded queue or an OOM.  Below the admission gate, every executing
query charges its decode work against one shared
:class:`~repro.core.scan.MorselBudget`, so even admitted queries cannot
stack unbounded in-flight morsels — concurrent scans throttle each other
cooperatively inside :class:`~repro.core.scan.ScanPlan`.

**Normalized-plan cache.**  Request specs are prepared once into unbound
:class:`~repro.core.query.Query` templates keyed by the raw spec; the
template's :meth:`~repro.core.query.Query.plan_key` canonicalizes the
fused expression tree (commuted conjuncts, shuffled ``isin`` values,
reordered projections all collapse to one key).

**Snapshot-consistent result cache.**  Each read pins the manifest
snapshot *first* (``Query`` binds the manifest, so concurrent commits
cannot shear a running query), then consults the result cache under
``(plan_key, generation)``.  Every response states the generation its rows
came from; a cached response is byte-identical to re-running the plan
against that generation.  MVCC commits bump the generation — in-process
commits additionally fire the
:func:`~repro.core.transactions.register_commit_listener` hook, which
eagerly drops the superseded generations' entries.

The module is importable without jax (the LM serving engine in
:mod:`repro.serve.engine` is untouched); ``python -m repro.serve.dbserver
--path DB --name DS`` runs a standalone server.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import hashlib
import json
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

from repro.core import LoadConfig, MorselBudget, ParquetDB
from repro.core.query import Query
from repro.core.transactions import register_commit_listener
from repro.serve.cache import CachedPlan, PlanCache, ResultCache, ServerStats
from repro.serve.protocol import (MAX_FRAME, ProtocolError, encode_frame,
                                  expr_from_json, read_frame)

__all__ = ["DBServer", "main"]

# request fields that define a read plan (order-free: raw keys are built
# with sort_keys, so two dicts with the same fields share one raw key)
_PLAN_FIELDS = ("op", "where", "select", "group_by", "agg", "order_by",
                "limit", "offset", "distinct")


class DBServer:
    """Serve one dataset over TCP.  See the module docstring.

    ``port=0`` binds an ephemeral port; :meth:`start` runs the server on a
    background thread and returns the bound ``(host, port)`` — the pattern
    the tests and the benchmark driver use.  For a foreground server call
    :meth:`serve_forever` (or use the CLI).
    """

    def __init__(self, db: ParquetDB, host: str = "127.0.0.1",
                 port: int = 0, *, max_concurrent: int = 4,
                 max_queue: int = 16,
                 morsel_budget: Optional[int] = None,
                 num_threads: Optional[int] = None,
                 plan_cache_entries: int = 512,
                 result_cache_entries: int = 256,
                 result_cache_bytes: int = 64 << 20,
                 max_frame: int = MAX_FRAME):
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        self._db = db
        self._host, self._port = host, int(port)
        self.max_concurrent = int(max_concurrent)
        self.max_queue = int(max_queue)
        self._max_frame = int(max_frame)
        budget_permits = (morsel_budget if morsel_budget is not None
                          else 2 * self.max_concurrent)
        self.budget = MorselBudget(budget_permits)
        self._cfg = LoadConfig(num_threads=num_threads,
                               morsel_budget=self.budget)
        self.plan_cache = PlanCache(plan_cache_entries)
        self.result_cache = ResultCache(result_cache_entries,
                                        result_cache_bytes)
        self.stats = ServerStats()
        self._pending = 0            # admitted, not yet finished (loop-only)
        self._sem: Optional[asyncio.Semaphore] = None
        self._exec = ThreadPoolExecutor(max_workers=self.max_concurrent,
                                        thread_name_prefix="dbserve")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._stop_evt: Optional[asyncio.Event] = None
        self._started = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._address: Optional[Tuple[str, int]] = None
        # eager invalidation on in-process commits; cross-process commits
        # are caught by the generation observed at snapshot-pin time
        self._unregister = register_commit_listener(
            db._dir.path, self._on_commit)

    # ------------------------------------------------------------ lifecycle
    @property
    def address(self) -> Tuple[str, int]:
        if self._address is None:
            raise RuntimeError("server not started")
        return self._address

    def start(self) -> Tuple[str, int]:
        """Run the server on a daemon thread; returns ``(host, port)``."""
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._amain()), daemon=True)
        self._thread.start()
        if not self._started.wait(timeout=10.0):
            raise RuntimeError("server failed to start within 10s")
        return self.address

    def stop(self) -> None:
        """Stop accepting, drain the executor, detach the commit listener."""
        if self._loop is not None and self._stop_evt is not None:
            try:
                self._loop.call_soon_threadsafe(self._stop_evt.set)
            except RuntimeError:
                pass  # loop already closed
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._exec.shutdown(wait=False)
        self._unregister()

    def serve_forever(self) -> None:
        """Run in the foreground until interrupted (the CLI entrypoint)."""
        asyncio.run(self._amain())

    async def _amain(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop_evt = asyncio.Event()
        self._sem = asyncio.Semaphore(self.max_concurrent)
        server = await asyncio.start_server(
            self._handle_conn, self._host, self._port)
        self._address = server.sockets[0].getsockname()[:2]
        self._started.set()
        async with server:
            await self._stop_evt.wait()

    def _on_commit(self, generation: int) -> None:
        self.result_cache.invalidate_below(generation)

    # ----------------------------------------------------------- connection
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    req = await read_frame(reader, self._max_frame)
                except ProtocolError as e:
                    # framing is broken: answer once, then hang up
                    writer.write(encode_frame(
                        {"status": 400, "error": str(e)}))
                    await writer.drain()
                    break
                if req is None:
                    break  # clean close
                resp = await self._dispatch(req)
                writer.write(encode_frame(resp))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _dispatch(self, req: Any) -> dict:
        if not isinstance(req, dict) or "op" not in req:
            self.stats.bump("errors")
            return {"status": 400, "error": "request must be an object "
                                            "with an 'op' field"}
        op = req["op"]
        if op == "ping":
            return {"status": 200, "pong": True}
        if op == "stats":
            return self._stats_response()
        if op not in ("query", "count", "agg", "explain",
                      "update", "delete"):
            self.stats.bump("errors")
            return {"status": 400, "error": f"unknown op {op!r}"}
        # -- admission control: bounded queue, immediate shed beyond it
        if self._pending >= self.max_concurrent + self.max_queue:
            self.stats.bump("shed")
            return {"status": 503, "error": "server busy",
                    "queue_depth": self._pending - self.max_concurrent,
                    "retry": True}
        self._pending += 1
        t0 = time.perf_counter()
        try:
            async with self._sem:
                resp = await self._loop.run_in_executor(
                    self._exec, self._execute, req)
        finally:
            self._pending -= 1
        self.stats.record((time.perf_counter() - t0) * 1e6)
        return resp

    def _stats_response(self) -> dict:
        return {"status": 200,
                "stats": self.stats.snapshot(),
                "budget": self.budget.stats(),
                "plan_cache_entries": len(self.plan_cache),
                "result_cache_entries": len(self.result_cache),
                "result_cache_bytes": self.result_cache.nbytes,
                "result_cache_invalidated": self.result_cache.invalidated,
                "result_cache_evicted": self.result_cache.evicted,
                "queue_depth": max(0, self._pending - self.max_concurrent),
                "max_concurrent": self.max_concurrent,
                "max_queue": self.max_queue}

    # ------------------------------------------------------------ execution
    def _execute(self, req: dict) -> dict:
        """Blocking half, runs on the executor; returns the response."""
        op = req["op"]
        try:
            if op in ("query", "count", "agg", "explain"):
                return self._execute_read(req)
            if op == "update":
                return self._execute_update(req)
            return self._execute_delete(req)
        except (ProtocolError, KeyError, TypeError, ValueError) as e:
            self.stats.bump("errors")
            return {"status": 400, "error": f"{type(e).__name__}: {e}"}
        except Exception as e:  # noqa: BLE001 — a query must not kill the server
            self.stats.bump("errors")
            return {"status": 500, "error": f"{type(e).__name__}: {e}"}

    def _prepare(self, req: dict) -> CachedPlan:
        """Raw spec -> CachedPlan via the normalized-plan cache."""
        raw_key = json.dumps({k: req.get(k) for k in _PLAN_FIELDS},
                             sort_keys=True, separators=(",", ":"),
                             default=str)
        plan = self.plan_cache.get(raw_key)
        if plan is not None:
            self.stats.bump("plan_hits")
            return plan
        q, scalar_agg, fp_suffix = self._build_query(req)
        fp = q.plan_fingerprint() + fp_suffix
        plan_key = hashlib.blake2b(fp.encode(), digest_size=16).hexdigest()
        plan = CachedPlan(plan_key, q, scalar_agg)
        self.plan_cache.put(raw_key, plan)
        self.stats.bump("plan_misses")
        return plan

    def _build_query(self, req: dict):
        """Decode one read request into an unbound Query template.

        Returns ``(query, scalar_agg, fingerprint_suffix)`` — the suffix
        distinguishes terminals that are not part of the builder state
        (``count``, ungrouped ``agg``) so they never share a plan key
        with a row-returning query of the same shape.
        """
        op = req["op"]
        q = self._db.query(load_config=self._cfg)
        if req.get("where") is not None:
            q = q.where(expr_from_json(req["where"]))
        if req.get("select") is not None:
            sel = req["select"]
            if not isinstance(sel, (list, tuple)):
                raise ProtocolError("select must be a list of column names")
            q = q.select(*sel)
        scalar_agg, fp_suffix = None, ""
        if op == "count":
            fp_suffix = "|terminal=count"
        elif op == "agg":
            spec = req.get("agg")
            if not isinstance(spec, dict) or not spec:
                raise ProtocolError("agg op needs a non-empty agg spec")
            scalar_agg = spec
            canon = ";".join(
                f"{c}:{'+'.join(sorted([ops] if isinstance(ops, str) else ops))}"
                for c, ops in sorted(spec.items()))
            fp_suffix = f"|terminal=agg|spec={canon}"
        else:  # query / explain
            if req.get("group_by") is not None:
                spec = req.get("agg")
                if not isinstance(spec, dict) or not spec:
                    raise ProtocolError("group_by needs a non-empty agg "
                                        "spec")
                q = q.group_by(*req["group_by"]).agg(spec)
            elif req.get("agg") is not None:
                raise ProtocolError("use op 'agg' for ungrouped "
                                    "aggregation")
            if req.get("distinct"):
                q = q.distinct()
        for entry in req.get("order_by") or []:
            if isinstance(entry, str):
                q = q.order_by(entry)
            elif (isinstance(entry, (list, tuple)) and len(entry) == 2):
                q = q.order_by(entry[0], desc=bool(entry[1]))
            else:
                raise ProtocolError(f"bad order_by entry {entry!r}")
        if req.get("limit") is not None:
            q = q.limit(int(req["limit"]))
        if req.get("offset"):
            q = q.offset(int(req["offset"]))
        return q, scalar_agg, fp_suffix

    def _execute_read(self, req: dict) -> dict:
        plan = self._prepare(req)
        # pin the snapshot FIRST: everything below — cache lookup, scan,
        # cache fill — is in terms of exactly this generation, so a commit
        # landing mid-request can neither shear the scan nor mis-key the
        # cached result
        man, _schema = self._db._load_snapshot()
        gen = man.generation
        self.stats.bump("queries")
        if req["op"] != "explain":
            cached = self.result_cache.get(plan.plan_key, gen)
            if cached is not None:
                self.stats.bump("result_hits")
                resp = dict(cached)
                resp["cache"] = "hit"
                return resp
            self.stats.bump("result_misses")
        q = plan.query._replace(man=man)  # bind the pinned snapshot
        resp: Dict[str, Any] = {"status": 200, "generation": gen,
                                "plan_key": plan.plan_key}
        if req["op"] == "explain":
            report = q.explain(execute=bool(req.get("execute")))
            resp["ops"] = [list(t) for t in report.ops]
            resp["counters"] = dataclasses.asdict(report.counters)
            resp["executed"] = report.executed
            return resp
        if req["op"] == "count":
            resp["count"] = q.count()
        elif req["op"] == "agg":
            resp["values"] = q.agg(plan.scalar_agg)
        else:
            resp["rows"] = q.to_pylist()
        nbytes = len(encode_frame(resp))
        self.result_cache.put(plan.plan_key, gen, dict(resp), nbytes)
        resp["cache"] = "miss"
        return resp

    def _execute_update(self, req: dict) -> dict:
        rows = req.get("rows")
        if not isinstance(rows, list) or not rows:
            raise ProtocolError("update needs a non-empty 'rows' list")
        n = self._db.update(rows)
        self.stats.bump("writes")
        gen = self._db._load_snapshot()[0].generation
        return {"status": 200, "updated": n, "generation": gen}

    def _execute_delete(self, req: dict) -> dict:
        ids = req.get("ids")
        filters = ([expr_from_json(req["where"])]
                   if req.get("where") is not None else None)
        if ids is None and filters is None:
            raise ProtocolError("delete needs 'ids' and/or 'where'")
        n = self._db.delete(ids=ids, filters=filters)
        self.stats.bump("writes")
        gen = self._db._load_snapshot()[0].generation
        return {"status": 200, "deleted": n, "generation": gen}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve one ParquetDB dataset over TCP "
                    "(length-prefixed JSON protocol)")
    ap.add_argument("--path", required=True, help="database directory")
    ap.add_argument("--name", required=True, help="dataset name")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7887)
    ap.add_argument("--max-concurrent", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=16)
    ap.add_argument("--morsel-budget", type=int, default=None)
    ap.add_argument("--num-threads", type=int, default=None)
    args = ap.parse_args(argv)
    db = ParquetDB(args.path, args.name)
    server = DBServer(db, args.host, args.port,
                      max_concurrent=args.max_concurrent,
                      max_queue=args.max_queue,
                      morsel_budget=args.morsel_budget,
                      num_threads=args.num_threads)
    print(f"serving {args.name} on {args.host}:{args.port}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
