"""Server-side caches: normalized plans, snapshot-consistent results, stats.

Two caches with one key between them:

- :class:`PlanCache` maps the *raw* request spec (canonical JSON of the
  wire fields) to a prepared, manifest-unbound
  :class:`~repro.core.query.Query` plus its canonical
  :meth:`~repro.core.query.Query.plan_key`.  A hit skips expression
  decoding, schema validation and fingerprinting.  The plan key is where
  normalization happens: requests that spell the same question differently
  (commuted ``where`` conjuncts, reordered ``select``, shuffled ``isin``
  values) map to *different* raw specs but the *same* plan key — so they
  converge on one result-cache entry.

- :class:`ResultCache` maps ``(plan_key, generation)`` to a finished
  response payload.  Keying on the manifest generation observed when the
  query's snapshot was pinned makes entries immutable facts: "this plan,
  over generation g, returns these rows" can never go stale — a commit
  doesn't corrupt old entries, it *supersedes* them by bumping the live
  generation, and the commit listener then drops the superseded
  generations' entries (memory hygiene; correctness never depended on the
  eviction happening).

Both are LRU with a lock around an ``OrderedDict`` — the server touches
them from worker threads.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["CachedPlan", "PlanCache", "ResultCache", "ServerStats"]


class CachedPlan:
    """One prepared plan: the unbound Query template + its canonical key.

    ``query`` has no manifest bound (``_man is None``); the server rebinds
    it to each request's pinned snapshot with ``_replace(man=...)`` — an
    O(slots) copy — so one template serves every generation.
    ``scalar_agg`` carries the normalized spec of an ungrouped ``agg``
    terminal (which is an argument of the terminal call, not part of the
    builder state, so it needs to ride along explicitly).
    """

    __slots__ = ("plan_key", "query", "scalar_agg", "hits")

    def __init__(self, plan_key: str, query, scalar_agg=None):
        self.plan_key = plan_key
        self.query = query
        self.scalar_agg = scalar_agg
        self.hits = 0


class PlanCache:
    """LRU of raw request spec -> :class:`CachedPlan`."""

    def __init__(self, max_entries: int = 512):
        self._max = int(max_entries)
        self._lock = threading.Lock()
        self._entries: "collections.OrderedDict[str, CachedPlan]" = \
            collections.OrderedDict()

    def get(self, raw_key: str) -> Optional[CachedPlan]:
        with self._lock:
            plan = self._entries.get(raw_key)
            if plan is not None:
                self._entries.move_to_end(raw_key)
                plan.hits += 1
            return plan

    def put(self, raw_key: str, plan: CachedPlan) -> CachedPlan:
        with self._lock:
            self._entries[raw_key] = plan
            self._entries.move_to_end(raw_key)
            while len(self._entries) > self._max:
                self._entries.popitem(last=False)
            return plan

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class ResultCache:
    """LRU of ``(plan_key, generation)`` -> response payload.

    Bounded by entry count and by total payload bytes (estimated from the
    encoded frame size the server already computed).  ``invalidate_below``
    drops every entry of a superseded generation — the commit listener's
    eager-invalidation hook; ``put`` also retires other generations of the
    same plan key opportunistically, which catches cross-process writers
    (they bump the generation without firing the in-process listener).
    """

    def __init__(self, max_entries: int = 256,
                 max_bytes: int = 64 << 20):
        self._max_entries = int(max_entries)
        self._max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._entries: "collections.OrderedDict[Tuple[str, int], Tuple[Any, int]]" = \
            collections.OrderedDict()
        self._bytes = 0
        self.invalidated = 0
        self.evicted = 0

    def get(self, plan_key: str, generation: int) -> Optional[Any]:
        with self._lock:
            hit = self._entries.get((plan_key, generation))
            if hit is None:
                return None
            self._entries.move_to_end((plan_key, generation))
            return hit[0]

    def put(self, plan_key: str, generation: int, payload: Any,
            nbytes: int) -> None:
        with self._lock:
            stale = [k for k in self._entries
                     if k[0] == plan_key and k[1] != generation]
            for k in stale:
                self._drop(k)
                self.invalidated += 1
            key = (plan_key, generation)
            if key in self._entries:
                self._drop(key)
            self._entries[key] = (payload, int(nbytes))
            self._bytes += int(nbytes)
            while (len(self._entries) > self._max_entries
                   or self._bytes > self._max_bytes):
                if len(self._entries) == 1:
                    break  # never evict the entry just written
                self._drop(next(iter(self._entries)))
                self.evicted += 1

    def _drop(self, key: Tuple[str, int]) -> None:
        payload = self._entries.pop(key, None)
        if payload is not None:
            self._bytes -= payload[1]

    def invalidate_below(self, generation: int) -> int:
        """Drop every entry whose generation predates ``generation``;
        returns how many were dropped."""
        with self._lock:
            stale = [k for k in self._entries if k[1] < generation]
            for k in stale:
                self._drop(k)
            self.invalidated += len(stale)
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes


class ServerStats:
    """Counters surfaced over the ``stats`` verb.

    ``record`` feeds a bounded latency reservoir (last ``maxlen``
    request latencies, reads and writes alike); :meth:`snapshot` computes
    p50/p99 from whatever the reservoir holds.  All mutation is behind one
    lock — the numbers are exact, not sampled, except the latency
    percentiles which are over the trailing window.
    """

    def __init__(self, latency_window: int = 4096):
        self._lock = threading.Lock()
        self._lat_us: "collections.deque[float]" = \
            collections.deque(maxlen=int(latency_window))
        self.queries = 0        # read-plan requests served (incl. cached)
        self.writes = 0         # update/delete requests applied
        self.shed = 0           # 503-rejected by admission control
        self.errors = 0         # 400/500 responses
        self.plan_hits = 0
        self.plan_misses = 0
        self.result_hits = 0
        self.result_misses = 0

    def record(self, latency_us: float) -> None:
        with self._lock:
            self._lat_us.append(float(latency_us))

    def bump(self, counter: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, counter, getattr(self, counter) + by)

    @staticmethod
    def _pct(sorted_lats: List[float], q: float) -> Optional[float]:
        if not sorted_lats:
            return None
        idx = min(len(sorted_lats) - 1, int(q * (len(sorted_lats) - 1)))
        return sorted_lats[idx]

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            lats = sorted(self._lat_us)
            return {
                "queries": self.queries,
                "writes": self.writes,
                "shed": self.shed,
                "errors": self.errors,
                "plan_hits": self.plan_hits,
                "plan_misses": self.plan_misses,
                "result_hits": self.result_hits,
                "result_misses": self.result_misses,
                "latency_samples": len(lats),
                "p50_us": self._pct(lats, 0.50),
                "p99_us": self._pct(lats, 0.99),
            }
