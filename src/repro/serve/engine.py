"""Batched serving engine: slot-based continuous batching over the decode step.

A fixed pool of B slots shares one KV cache (the cache's batch dim).  New
requests prefill into a free slot; every engine step decodes one token for all
active slots (idle slots compute garbage that is masked out — the standard
static-batch trade).  Per-slot positions require the vector-``pos`` decode
path in :mod:`repro.models.attention`.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, max_seq: int = 256,
                 mesh=None, greedy: bool = True):
        self.model, self.params, self.mesh = model, params, mesh
        self.slots, self.max_seq = slots, max_seq
        self.cache = model.init_cache(slots, max_seq)
        self.pos = np.full(slots, -1, np.int64)        # -1 = free
        self.active: Dict[int, Request] = {}
        self._ids = itertools.count()
        self.queue: List[Request] = []
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, mesh=mesh))

    # -- API ---------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        r = Request(next(self._ids), np.asarray(prompt, np.int32),
                    max_new_tokens, eos_id)
        self.queue.append(r)
        return r.rid

    def step(self) -> List[Request]:
        """Admit + decode one token for all active slots; returns finished."""
        self._admit()
        finished: List[Request] = []
        if not self.active:
            return finished
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, r in self.active.items():
            last = (r.out_tokens[-1] if r.out_tokens else int(r.prompt[-1]))
            tokens[slot, 0] = last
        pos = jnp.asarray(np.maximum(self.pos, 0).astype(np.int32))
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens), pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for slot, r in list(self.active.items()):
            tok = int(nxt[slot])
            r.out_tokens.append(tok)
            self.pos[slot] += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or (r.eos_id is not None and tok == r.eos_id)
                    or self.pos[slot] >= self.max_seq - 1):
                r.done = True
                finished.append(r)
                del self.active[slot]
                self.pos[slot] = -1
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> List[Request]:
        out: List[Request] = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.active and not self.queue:
                break
        return out

    # -- internals ---------------------------------------------------------------
    def _admit(self):
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop(0)
            r = self.queue.pop(0)
            r.slot = slot
            # prefill the prompt into this slot, token by token through the
            # decode path (slot-local; avoids a second compiled prefill shape)
            for i, tok in enumerate(r.prompt[:-1]):
                t = np.zeros((self.slots, 1), np.int32)
                t[slot, 0] = int(tok)
                pos_vec = np.maximum(self.pos, 0)
                pos_vec[slot] = i
                _, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(t),
                    jnp.asarray(pos_vec.astype(np.int32)))
            self.pos[slot] = len(r.prompt) - 1
            self.active[slot] = r
