"""Serving substrate: KV-cache decode engine with continuous batching."""
