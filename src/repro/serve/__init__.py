"""Serving substrate.

Two independent serving tiers live here:

- :mod:`repro.serve.engine` — the LM decode engine (KV-cache slots,
  continuous batching); requires jax.
- :mod:`repro.serve.dbserver` (+ :mod:`~repro.serve.protocol`,
  :mod:`~repro.serve.cache`) — the database query server: asyncio TCP,
  admission control over a shared morsel budget, normalized-plan and
  snapshot-consistent result caches; pure stdlib + numpy, no jax.

Nothing is imported eagerly so that ``repro.serve.dbserver`` stays usable
in jax-free environments (CI docs/examples jobs, lean deployments).
"""
