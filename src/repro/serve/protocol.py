"""Wire protocol for the DB serving tier: length-prefixed JSON frames.

A frame is a 4-byte big-endian unsigned length followed by that many bytes
of UTF-8 JSON — trivially parseable from any language, self-delimiting on
a stream, and friendly to request pipelining (a client may queue several
request frames on one connection; responses come back in order).  Frames
above ``MAX_FRAME`` are refused before the payload is read, so a garbage
length prefix cannot make the server allocate gigabytes.

Requests are JSON objects with an ``op`` — ``query``, ``count``, ``agg``,
``update``, ``delete``, ``explain``, ``stats``, ``ping`` — plus op-specific
fields (see :mod:`repro.serve.dbserver` for the full surface).  Responses
always carry ``status`` (HTTP-flavored: 200 OK, 400 bad request, 503 shed)
and, for reads, the ``generation`` of the manifest snapshot that produced
the rows — the server's snapshot-consistency contract is that every value
in one response comes from exactly that generation.

Filter expressions travel as s-expression-style JSON arrays and are decoded
into :mod:`repro.core.expressions` trees server-side::

    ["cmp", "age", ">=", 30]
    ["isin", "city", ["Portland", "Austin"]]
    ["and", ["cmp", "age", ">=", 30], ["not", ["isnull", "email"]]]

:class:`DBClient` is the blocking reference client used by the tests, the
benchmark driver and the docs examples.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Any, List, Optional, Sequence

from repro.core.expressions import (And, Comparison, Expr, FieldRef, IsIn,
                                    IsNaN, IsNull, Not, Or, field)

__all__ = ["MAX_FRAME", "ProtocolError", "encode_frame", "read_frame",
           "recv_frame", "expr_to_json", "expr_from_json", "DBClient"]

_HEADER = struct.Struct(">I")
MAX_FRAME = 64 << 20  # 64 MiB per frame: far above any sane request/response

_CMP_OPS = ("==", "!=", "<", "<=", ">", ">=")


class ProtocolError(ValueError):
    """Malformed frame or expression spec (maps to a 400 response)."""


def encode_frame(obj: Any) -> bytes:
    """JSON-encode ``obj`` and prepend the 4-byte length header."""
    payload = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(payload)} bytes exceeds "
                            f"MAX_FRAME={MAX_FRAME}")
    return _HEADER.pack(len(payload)) + payload


async def read_frame(reader, max_frame: int = MAX_FRAME) -> Optional[Any]:
    """Read one frame from an asyncio StreamReader.

    Returns the decoded object, or ``None`` on clean EOF (peer closed
    between frames).  A mid-frame EOF or an oversized length raises
    :class:`ProtocolError`.
    """
    import asyncio
    try:
        header = await reader.readexactly(_HEADER.size)
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None  # clean close between frames
        raise ProtocolError("connection closed mid-header") from None
    (length,) = _HEADER.unpack(header)
    if length > max_frame:
        raise ProtocolError(f"frame of {length} bytes exceeds "
                            f"max_frame={max_frame}")
    try:
        payload = await reader.readexactly(length)
    except asyncio.IncompleteReadError:
        raise ProtocolError("connection closed mid-frame") from None
    try:
        return json.loads(payload.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"undecodable frame: {e}") from None


def _recv_exactly(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ProtocolError("connection closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket, max_frame: int = MAX_FRAME) -> Any:
    """Blocking-socket twin of :func:`read_frame` (for sync clients)."""
    (length,) = _HEADER.unpack(_recv_exactly(sock, _HEADER.size))
    if length > max_frame:
        raise ProtocolError(f"frame of {length} bytes exceeds "
                            f"max_frame={max_frame}")
    return json.loads(_recv_exactly(sock, length).decode("utf-8"))


# ---------------------------------------------------------------------------
# expression codec
# ---------------------------------------------------------------------------
def expr_to_json(e: Expr) -> list:
    """Render an Expr tree as the wire's s-expression JSON form."""
    if isinstance(e, And):
        return ["and", expr_to_json(e.a), expr_to_json(e.b)]
    if isinstance(e, Or):
        return ["or", expr_to_json(e.a), expr_to_json(e.b)]
    if isinstance(e, Not):
        return ["not", expr_to_json(e.a)]
    if isinstance(e, Comparison):
        v = (["field", e.value.name] if isinstance(e.value, FieldRef)
             else e.value)
        return ["cmp", e.name, e.op, v]
    if isinstance(e, IsIn):
        return ["isin", e.name, list(e.values)]
    if isinstance(e, IsNull):
        return ["isvalid" if e._negated else "isnull", e.name]
    if isinstance(e, IsNaN):
        return ["isnan", e.name]
    raise ProtocolError(f"expression {type(e).__name__} has no wire form")


def expr_from_json(spec: Any) -> Expr:
    """Decode the wire's s-expression JSON form back into an Expr tree."""
    if not isinstance(spec, (list, tuple)) or not spec:
        raise ProtocolError(f"expression spec must be a non-empty list, "
                            f"got {spec!r}")
    tag, *rest = spec
    if tag == "and" and len(rest) == 2:
        return And(expr_from_json(rest[0]), expr_from_json(rest[1]))
    if tag == "or" and len(rest) == 2:
        return Or(expr_from_json(rest[0]), expr_from_json(rest[1]))
    if tag == "not" and len(rest) == 1:
        return Not(expr_from_json(rest[0]))
    if tag == "cmp" and len(rest) == 3:
        name, op, value = rest
        if op not in _CMP_OPS:
            raise ProtocolError(f"unknown comparison op {op!r}")
        if (isinstance(value, (list, tuple)) and len(value) == 2
                and value[0] == "field"):
            value = field(value[1])
        return Comparison(name, op, value)
    if tag == "isin" and len(rest) == 2:
        name, values = rest
        if not isinstance(values, (list, tuple)):
            raise ProtocolError("isin values must be a list")
        return IsIn(name, list(values))
    if tag == "isnull" and len(rest) == 1:
        return IsNull(rest[0])
    if tag == "isvalid" and len(rest) == 1:
        return IsNull(rest[0], negate=True)
    if tag == "isnan" and len(rest) == 1:
        return IsNaN(rest[0])
    raise ProtocolError(f"bad expression spec {spec!r}")


# ---------------------------------------------------------------------------
# blocking reference client
# ---------------------------------------------------------------------------
class DBClient:
    """Blocking client for :class:`~repro.serve.dbserver.DBServer`.

    One TCP connection, requests answered in order.  ``where`` arguments
    accept either the wire's JSON list form or an
    :class:`~repro.core.expressions.Expr` built with ``field(...)`` (the
    client encodes it).  Responses are returned as decoded JSON dicts —
    callers check ``resp["status"]`` (503 means shed by admission control:
    back off and retry).  Usable as a context manager.
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def request(self, req: dict) -> dict:
        self._sock.sendall(encode_frame(req))
        return recv_frame(self._sock)

    @staticmethod
    def _where_spec(where) -> Optional[list]:
        if where is None:
            return None
        return expr_to_json(where) if isinstance(where, Expr) else where

    def query(self, where=None, select: Optional[Sequence[str]] = None,
              group_by: Optional[Sequence[str]] = None, agg=None,
              order_by=None, limit: Optional[int] = None, offset: int = 0,
              distinct: bool = False) -> dict:
        """The full builder surface in one request; rows come back as a
        list of name-addressed records under ``"rows"``."""
        req: dict = {"op": "query"}
        if where is not None:
            req["where"] = self._where_spec(where)
        if select is not None:
            req["select"] = list(select)
        if group_by is not None:
            req["group_by"] = list(group_by)
        if agg is not None:
            req["agg"] = agg
        if order_by is not None:
            req["order_by"] = order_by
        if limit is not None:
            req["limit"] = int(limit)
        if offset:
            req["offset"] = int(offset)
        if distinct:
            req["distinct"] = True
        return self.request(req)

    def count(self, where=None) -> dict:
        req: dict = {"op": "count"}
        if where is not None:
            req["where"] = self._where_spec(where)
        return self.request(req)

    def agg(self, spec, where=None) -> dict:
        """Ungrouped aggregation (footer-statistics fast path server-side);
        scalars come back under ``"values"``."""
        req: dict = {"op": "agg", "agg": spec}
        if where is not None:
            req["where"] = self._where_spec(where)
        return self.request(req)

    def update(self, rows: List[dict]) -> dict:
        return self.request({"op": "update", "rows": rows})

    def delete(self, ids: Optional[Sequence[int]] = None,
               where=None) -> dict:
        req: dict = {"op": "delete"}
        if ids is not None:
            req["ids"] = [int(i) for i in ids]
        if where is not None:
            req["where"] = self._where_spec(where)
        return self.request(req)

    def explain(self, **query_fields) -> dict:
        req = {"op": "explain"}
        req.update({k: (self._where_spec(v) if k == "where" else v)
                    for k, v in query_fields.items()})
        return self.request(req)

    def stats(self) -> dict:
        return self.request({"op": "stats"})

    def ping(self) -> dict:
        return self.request({"op": "ping"})

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "DBClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
